//! Experiment configuration: a typed schema over the in-tree JSON parser,
//! loadable from a file and overridable from the CLI (`--set key=value`).

pub mod json;

use std::path::Path;

use anyhow::{bail, Context, Result};

pub use json::Json;

pub use crate::model::state::Kernel;
pub use crate::obs::ObsLevel;

/// Which sampler drives the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    Hybrid,
    Collapsed,
    Accelerated,
    Uncollapsed,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "hybrid" => Self::Hybrid,
            "collapsed" => Self::Collapsed,
            "accelerated" => Self::Accelerated,
            "uncollapsed" => Self::Uncollapsed,
            _ => bail!("unknown sampler '{s}' (hybrid|collapsed|accelerated|uncollapsed)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Hybrid => "hybrid",
            Self::Collapsed => "collapsed",
            Self::Accelerated => "accelerated",
            Self::Uncollapsed => "uncollapsed",
        }
    }
}

/// Numeric backend for the hybrid workers' hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust f64 sweep (always available; the cross-check oracle).
    Native,
    /// AOT-compiled JAX/Pallas executables via PJRT (`artifacts/`).
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "native" => Self::Native,
            "pjrt" => Self::Pjrt,
            _ => bail!("unknown backend '{s}' (native|pjrt)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Native => "native",
            Self::Pjrt => "pjrt",
        }
    }
}

/// The communication model used by virtual-time accounting
/// (DESIGN.md §Substitutions: stands in for the paper's MPI cluster).
#[derive(Clone, Copy, Debug)]
pub struct CommModel {
    /// Per-message latency, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        // commodity-Ethernet-ish: 50 µs latency, 1 GiB/s
        Self { latency_s: 50e-6, bandwidth_bps: 1024.0 * 1024.0 * 1024.0 }
    }
}

impl CommModel {
    /// Modelled transfer time for one message of `bytes`.
    pub fn cost(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

/// Everything a run needs. Defaults reproduce the paper's Figure-1 setup.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: String,
    pub n: usize,
    pub k_true: usize,
    pub dim: usize,
    pub data_sigma_x: f64,
    pub sampler: SamplerKind,
    pub backend: Backend,
    pub processors: usize,
    /// Intra-worker sweep threads T (deterministic fork-join; identical
    /// chains for every value — see `crate::parallel`).
    pub threads_per_worker: usize,
    /// Z storage kernel: `scalar` (one byte per bit) or `packed` (u64
    /// words, popcount gram). Like T, bit-invariant — the chain is
    /// identical for either value, so resume may switch it freely.
    pub kernel: Kernel,
    pub sub_iters: usize,
    pub iters: usize,
    pub seed: u64,
    pub alpha: f64,
    pub sigma_x: f64,
    pub sigma_a: f64,
    pub sample_hypers: bool,
    pub heldout_frac: f64,
    pub eval_every: usize,
    pub eval_sweeps: usize,
    pub kmax_new: usize,
    pub k_cap: usize,
    pub artifacts_dir: String,
    pub out_dir: String,
    pub comm: CommModel,
    /// Write a full sampler checkpoint every this many iterations
    /// (`crate::snapshot`; 0 = off). A final checkpoint is also written
    /// when the run completes, so `pibp predict` always has an artifact.
    pub checkpoint_every: usize,
    /// Checkpoint file path ("" = `<out_dir>/checkpoint.pibp`).
    pub checkpoint_path: String,
    /// Posterior-sample reservoir capacity (`crate::serve`; 0 = off).
    /// Samples are thinned deterministically to stay within capacity.
    pub keep_samples: usize,
    /// Trace thinning stride: keep every k-th recorded evaluation point
    /// (1 = keep all) so long checkpointed chains bound trace memory.
    pub trace_thin: usize,
    /// Runtime observability level (`crate::obs`): `off`, `counters`
    /// (sampler-health counters + K⁺ trajectory) or `full` (adds phase
    /// span timers). Provably non-perturbing — excluded from the resume
    /// fingerprint like `threads_per_worker` and `kernel`, so a resumed
    /// run may toggle it freely.
    pub obs: ObsLevel,
    /// Obs report path ("" = `<out_dir>/run_obs.json` when obs is on).
    /// Flushed at the checkpoint cadence and at run end.
    pub obs_out: String,
    /// Replica chains for convergence diagnostics (`pibp run --chains`).
    /// Chain c runs the same config with seed `chain_seed(seed, c)`
    /// (chain 0 keeps the root seed); streaming ESS / split-R̂ land in
    /// the `diag` section of the obs report. Like `obs`, excluded from
    /// the resume fingerprint: diagnostics never perturb any chain
    /// (`rust/tests/diag_equivalence.rs`). Clamped to ≥ 1.
    pub chains: usize,
    /// Deterministic early-stop rule over the streaming diagnostics,
    /// e.g. `"rhat<1.01,ess>200"` ("" = run the full horizon). The
    /// trigger iteration is recorded in the report; a standalone run
    /// with `iters` set to it reproduces the stopped chains exactly.
    pub until: String,
    /// Trace export path ("" = off): `.json` keeps full f64 precision,
    /// anything else writes the rounded CSV. With `chains > 1`, chain c
    /// writes to the path with `.c<c>` inserted before the extension.
    pub trace_out: String,
    /// Master↔worker message plane: `channel` (in-process worker threads,
    /// the default), `uds` or `tcp` (real `pibp worker --connect`
    /// processes). Bit-invariant — the chain bytes must not depend on how
    /// bytes move (`rust/tests/process_equivalence.rs`) — so, like
    /// `kernel` and `obs`, it is excluded from the resume fingerprint.
    pub transport: String,
    /// Listen address for `transport=uds` (socket path) / `tcp`
    /// (`host:port`). Must be empty for `transport=channel`.
    pub listen: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            dataset: "cambridge".into(),
            n: 1000,
            k_true: 4,
            dim: 36,
            data_sigma_x: 0.5,
            sampler: SamplerKind::Hybrid,
            backend: Backend::Native,
            processors: 1,
            threads_per_worker: 1,
            kernel: Kernel::Scalar,
            sub_iters: 5,
            iters: 1000,
            seed: 0,
            alpha: 1.0,
            sigma_x: 0.5,
            sigma_a: 1.0,
            sample_hypers: true,
            heldout_frac: 0.1,
            eval_every: 5,
            eval_sweeps: 3,
            kmax_new: 4,
            k_cap: 64,
            artifacts_dir: "artifacts".into(),
            out_dir: "results".into(),
            comm: CommModel::default(),
            checkpoint_every: 0,
            checkpoint_path: String::new(),
            keep_samples: 0,
            trace_thin: 1,
            obs: ObsLevel::Off,
            obs_out: String::new(),
            chains: 1,
            until: String::new(),
            trace_out: String::new(),
            transport: "channel".into(),
            listen: String::new(),
        }
    }
}

impl RunConfig {
    /// Load from a JSON file (all keys optional; unknown keys rejected).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text)?;
        let mut cfg = Self::default();
        let Json::Obj(map) = &v else { bail!("config root must be an object") };
        for (key, val) in map {
            let raw = match val {
                Json::Str(s) => s.clone(),
                other => other.to_string(),
            };
            cfg.apply(key, &raw)?;
        }
        Ok(cfg)
    }

    /// Apply one `key=value` override (CLI `--set`).
    pub fn apply(&mut self, key: &str, value: &str) -> Result<()> {
        let uint = || -> Result<usize> {
            value.parse().with_context(|| format!("{key}={value} (want uint)"))
        };
        let float = || -> Result<f64> {
            value.parse().with_context(|| format!("{key}={value} (want float)"))
        };
        let boolean = || -> Result<bool> {
            value.parse().with_context(|| format!("{key}={value} (want bool)"))
        };
        match key {
            "dataset" => self.dataset = value.into(),
            "n" => self.n = uint()?,
            "k_true" => self.k_true = uint()?,
            "dim" => self.dim = uint()?,
            "data_sigma_x" => self.data_sigma_x = float()?,
            "sampler" => self.sampler = SamplerKind::parse(value)?,
            "backend" => self.backend = Backend::parse(value)?,
            "processors" => self.processors = uint()?,
            // clamped, not rejected: T is a pure scheduling knob, so
            // `--threads 0` from any entry point (JSON, --set, CLI flags)
            // means "run inline", exactly like T=1 — see crate::parallel
            "threads_per_worker" => self.threads_per_worker = uint()?.max(1),
            "kernel" => self.kernel = Kernel::parse(value)?,
            "sub_iters" => self.sub_iters = uint()?,
            "iters" => self.iters = uint()?,
            "seed" => self.seed = value.parse()?,
            "alpha" => self.alpha = float()?,
            "sigma_x" => self.sigma_x = float()?,
            "sigma_a" => self.sigma_a = float()?,
            "sample_hypers" => self.sample_hypers = boolean()?,
            "heldout_frac" => self.heldout_frac = float()?,
            "eval_every" => self.eval_every = uint()?,
            "eval_sweeps" => self.eval_sweeps = uint()?,
            "kmax_new" => self.kmax_new = uint()?,
            "k_cap" => self.k_cap = uint()?,
            "artifacts_dir" => self.artifacts_dir = value.into(),
            "out_dir" => self.out_dir = value.into(),
            "comm_latency_us" => self.comm.latency_s = float()? * 1e-6,
            // seconds directly — the canonical (checkpoint) serialisation
            // uses this key because `µs → s` multiplies by a non-power-of-
            // two and is not bit-exact round-trip; gbps is fine (2³⁰ is)
            "comm_latency_s" => self.comm.latency_s = float()?,
            "comm_bandwidth_gbps" => {
                self.comm.bandwidth_bps = float()? * 1024.0 * 1024.0 * 1024.0
            }
            "checkpoint_every" => self.checkpoint_every = uint()?,
            "checkpoint_path" => self.checkpoint_path = value.into(),
            "keep_samples" => self.keep_samples = uint()?,
            "trace_thin" => self.trace_thin = uint()?,
            "obs" => self.obs = ObsLevel::parse(value)?,
            "obs_out" => self.obs_out = value.into(),
            // clamped like threads_per_worker: 0 replica chains is
            // nonsensical, and a diagnostics knob shouldn't hard-error
            "chains" => self.chains = uint()?.max(1),
            "until" => self.until = value.into(),
            "trace_out" => self.trace_out = value.into(),
            "transport" => self.transport = value.into(),
            "listen" => self.listen = value.into(),
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.processors == 0 {
            bail!("processors must be ≥ 1");
        }
        // threads_per_worker needs no validation: `apply` clamps 0 to 1,
        // and every executor entry point (ParallelCtx / ExecConfig /
        // ThreadPool constructors) clamps again, so a hand-built 0 simply
        // runs inline.
        if self.n < self.processors {
            bail!("need at least one row per processor");
        }
        if !(0.0..1.0).contains(&self.heldout_frac) {
            bail!("heldout_frac must be in [0, 1)");
        }
        if self.sigma_x <= 0.0 || self.sigma_a <= 0.0 || self.alpha <= 0.0 {
            bail!("sigma_x, sigma_a, alpha must be positive");
        }
        if self.trace_thin == 0 {
            bail!("trace_thin must be ≥ 1 (1 keeps every point)");
        }
        if (self.checkpoint_every > 0 || self.keep_samples > 0)
            && self.sampler != SamplerKind::Hybrid
        {
            bail!(
                "checkpoint_every / keep_samples require the hybrid sampler \
                 (the serial baselines have no durable-state support)"
            );
        }
        if (self.chains > 1 || !self.until.is_empty())
            && self.sampler != SamplerKind::Hybrid
        {
            bail!(
                "chains > 1 / until require the hybrid sampler (the \
                 multi-chain runner replicates the coordinator per chain)"
            );
        }
        // reject a malformed early-stop rule up front, not mid-run
        crate::metrics::StopRule::parse(&self.until)?;
        // transport must parse (channel|uds|tcp; uds/tcp require listen)
        let transport =
            crate::coordinator::TransportConfig::parse(&self.transport, &self.listen)?;
        if transport == crate::coordinator::TransportConfig::Channel
            && !self.listen.is_empty()
        {
            bail!("listen is set but transport=channel ignores it — \
                   set transport=uds or transport=tcp");
        }
        if transport != crate::coordinator::TransportConfig::Channel {
            if self.sampler != SamplerKind::Hybrid {
                bail!(
                    "transport={} requires the hybrid sampler (only the \
                     coordinator has workers to distribute)",
                    self.transport
                );
            }
            if self.chains > 1 {
                bail!(
                    "chains > 1 requires transport=channel (each replica \
                     chain would need its own listen address)"
                );
            }
        }
        Ok(())
    }

    /// Canonical `key=value` serialisation of *every* settable field, in
    /// a fixed order, using the same keys [`Self::apply`] accepts — so a
    /// config can be reconstructed from the text with
    /// [`Self::from_canonical`]. Stored verbatim inside checkpoints:
    /// `pibp resume` needs no external config file.
    pub fn canonical(&self) -> String {
        format!(
            "dataset={}\nn={}\nk_true={}\ndim={}\ndata_sigma_x={}\n\
             sampler={}\nbackend={}\nprocessors={}\nthreads_per_worker={}\n\
             kernel={}\n\
             sub_iters={}\niters={}\nseed={}\nalpha={}\nsigma_x={}\n\
             sigma_a={}\nsample_hypers={}\nheldout_frac={}\neval_every={}\n\
             eval_sweeps={}\nkmax_new={}\nk_cap={}\nartifacts_dir={}\n\
             out_dir={}\ncomm_latency_s={}\ncomm_bandwidth_gbps={}\n\
             checkpoint_every={}\ncheckpoint_path={}\nkeep_samples={}\n\
             trace_thin={}\nobs={}\nobs_out={}\nchains={}\nuntil={}\n\
             trace_out={}\ntransport={}\nlisten={}\n",
            self.dataset,
            self.n,
            self.k_true,
            self.dim,
            self.data_sigma_x,
            self.sampler.name(),
            self.backend.name(),
            self.processors,
            self.threads_per_worker,
            self.kernel.name(),
            self.sub_iters,
            self.iters,
            self.seed,
            self.alpha,
            self.sigma_x,
            self.sigma_a,
            self.sample_hypers,
            self.heldout_frac,
            self.eval_every,
            self.eval_sweeps,
            self.kmax_new,
            self.k_cap,
            self.artifacts_dir,
            self.out_dir,
            self.comm.latency_s,
            self.comm.bandwidth_bps / (1024.0 * 1024.0 * 1024.0),
            self.checkpoint_every,
            self.checkpoint_path,
            self.keep_samples,
            self.trace_thin,
            self.obs.name(),
            self.obs_out,
            self.chains,
            self.until,
            self.trace_out,
            self.transport,
            self.listen,
        )
    }

    /// Reconstruct a config from [`Self::canonical`] text (replays every
    /// line through [`Self::apply`], so unknown keys are rejected).
    pub fn from_canonical(text: &str) -> Result<Self> {
        let mut cfg = Self::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("canonical config line '{line}' has no '='"))?;
            cfg.apply(k, v)?;
        }
        Ok(cfg)
    }

    /// Chain fingerprint: an FNV-1a hash over exactly the fields that
    /// determine the Markov chain and its evaluation stream — dataset
    /// identity/shape, sampler, backend, P, L, seed, priors, hyper
    /// sampling, held-out split and evaluation schedule, and the tail
    /// proposal caps. Deliberately *excluded*: `threads_per_worker` (T is
    /// bit-invariant by the `crate::parallel` contract), `kernel` (packed
    /// and scalar Z storage produce bit-identical chains, so resume may
    /// switch reprs), `iters` (resume
    /// extends the horizon), checkpoint/serving knobs, output/artifact
    /// paths, the comm model (virtual-time accounting only), and the
    /// `obs`/`obs_out` observability keys (observation never perturbs the
    /// chain — `rust/tests/obs_equivalence.rs` — so resume may toggle it
    /// mid-run at any checkpoint boundary), and the
    /// `chains`/`until`/`trace_out` diagnostics keys (streaming ESS/R̂
    /// is read-only on kept trace points and draws no RNG —
    /// `rust/tests/diag_equivalence.rs` — so they are equally free to
    /// change across a resume), and the `transport`/`listen` keys (the
    /// chain bytes must not depend on how bytes move — a P-worker run
    /// over sockets is bit-identical to the same run in-process,
    /// `rust/tests/process_equivalence.rs` — so a checkpoint written
    /// in-process may resume over UDS/TCP and vice versa). `pibp
    /// resume` refuses a checkpoint whose fingerprint differs from the
    /// resumed configuration's.
    pub fn fingerprint(&self) -> u64 {
        let chain = format!(
            "dataset={}\nn={}\nk_true={}\ndim={}\ndata_sigma_x={}\n\
             sampler={}\nbackend={}\nprocessors={}\nsub_iters={}\nseed={}\n\
             alpha={}\nsigma_x={}\nsigma_a={}\nsample_hypers={}\n\
             heldout_frac={}\neval_every={}\neval_sweeps={}\nkmax_new={}\n\
             k_cap={}\n",
            self.dataset,
            self.n,
            self.k_true,
            self.dim,
            self.data_sigma_x,
            self.sampler.name(),
            self.backend.name(),
            self.processors,
            self.sub_iters,
            self.seed,
            self.alpha,
            self.sigma_x,
            self.sigma_a,
            self.sample_hypers,
            self.heldout_frac,
            self.eval_every,
            self.eval_sweeps,
            self.kmax_new,
            self.k_cap,
        );
        crate::snapshot::fnv1a(chain.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_setup() {
        let c = RunConfig::default();
        assert_eq!(c.n, 1000);
        assert_eq!(c.dim, 36);
        assert_eq!(c.sub_iters, 5);
        assert_eq!(c.iters, 1000);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn apply_overrides() {
        let mut c = RunConfig::default();
        c.apply("processors", "5").unwrap();
        c.apply("threads_per_worker", "4").unwrap();
        c.apply("sampler", "collapsed").unwrap();
        c.apply("sigma_x", "0.25").unwrap();
        c.apply("sample_hypers", "false").unwrap();
        assert_eq!(c.processors, 5);
        assert_eq!(c.threads_per_worker, 4);
        assert_eq!(c.sampler, SamplerKind::Collapsed);
        assert!(!c.sample_hypers);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let mut c = RunConfig::default();
        assert!(c.apply("procesors", "5").is_err());
        assert!(c.apply("processors", "five").is_err());
        assert!(c.apply("sampler", "gibbs").is_err());
    }

    #[test]
    fn validate_catches_bad_configs() {
        let mut c = RunConfig::default();
        c.processors = 0;
        assert!(c.validate().is_err());
        c.processors = 2000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn threads_zero_clamps_to_inline_everywhere() {
        // config entry point: --set threads_per_worker=0 / JSON 0 → 1
        let mut c = RunConfig::default();
        c.apply("threads_per_worker", "0").unwrap();
        assert_eq!(c.threads_per_worker, 1);
        // a hand-built 0 is tolerated by validate (executors clamp too)
        c.threads_per_worker = 0;
        assert!(c.validate().is_ok());
        // executor entry points
        assert_eq!(crate::parallel::ExecConfig::with_threads(0).threads(), 1);
        assert_eq!(crate::parallel::ParallelCtx::pooled(0).threads(), 1);
        assert_eq!(crate::parallel::ThreadPool::new(0).threads(), 1);
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir().join("pibp_cfg");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, r#"{"processors": 3, "sampler": "hybrid", "iters": 10}"#).unwrap();
        let c = RunConfig::from_file(&p).unwrap();
        assert_eq!(c.processors, 3);
        assert_eq!(c.iters, 10);
        assert_eq!(c.sampler, SamplerKind::Hybrid);
    }

    #[test]
    fn canonical_roundtrips_through_apply() {
        let mut c = RunConfig::default();
        c.apply("processors", "5").unwrap();
        c.apply("dataset", "synth").unwrap();
        c.apply("seed", "99").unwrap();
        c.apply("sigma_x", "0.3725").unwrap();
        c.apply("checkpoint_every", "25").unwrap();
        c.apply("checkpoint_path", "out/state.pibp").unwrap();
        c.apply("keep_samples", "16").unwrap();
        c.apply("trace_thin", "4").unwrap();
        c.apply("kernel", "packed").unwrap();
        c.apply("obs", "counters").unwrap();
        c.apply("obs_out", "out/run_obs.json").unwrap();
        c.apply("chains", "3").unwrap();
        c.apply("until", "rhat<1.01,ess>200").unwrap();
        c.apply("trace_out", "out/trace.json").unwrap();
        c.apply("transport", "uds").unwrap();
        c.apply("listen", "/tmp/pibp.sock").unwrap();
        let back = RunConfig::from_canonical(&c.canonical()).unwrap();
        assert_eq!(back.transport, "uds");
        assert_eq!(back.listen, "/tmp/pibp.sock");
        assert_eq!(back.kernel, Kernel::Packed);
        assert_eq!(back.obs, ObsLevel::Counters);
        assert_eq!(back.obs_out, "out/run_obs.json");
        assert_eq!(back.chains, 3);
        assert_eq!(back.until, "rhat<1.01,ess>200");
        assert_eq!(back.trace_out, "out/trace.json");
        assert_eq!(back.processors, 5);
        assert_eq!(back.dataset, "synth");
        assert_eq!(back.seed, 99);
        assert_eq!(back.sigma_x.to_bits(), 0.3725f64.to_bits());
        assert_eq!(back.checkpoint_every, 25);
        assert_eq!(back.checkpoint_path, "out/state.pibp");
        assert_eq!(back.keep_samples, 16);
        assert_eq!(back.trace_thin, 4);
        // and the chain fingerprint survives the text roundtrip
        assert_eq!(back.fingerprint(), c.fingerprint());
        // the comm model round-trips bit-exactly (canonical stores
        // latency in seconds; µs would double-round by one ulp)
        assert_eq!(back.comm.latency_s.to_bits(), c.comm.latency_s.to_bits());
        assert_eq!(
            back.comm.bandwidth_bps.to_bits(),
            c.comm.bandwidth_bps.to_bits()
        );
    }

    #[test]
    fn fingerprint_tracks_chain_keys_only() {
        let base = RunConfig::default();
        // T, iters and checkpoint knobs must NOT change the fingerprint
        let mut c = base.clone();
        c.threads_per_worker = 8;
        c.iters = 5000;
        c.checkpoint_every = 10;
        c.keep_samples = 32;
        c.out_dir = "elsewhere".into();
        // the storage kernel is bit-invariant, so resume may switch it
        c.kernel = Kernel::Packed;
        // observability never perturbs the chain, so resume may toggle it
        c.obs = ObsLevel::Full;
        c.obs_out = "elsewhere/run_obs.json".into();
        // diagnostics are equally non-perturbing: a replica checkpoint
        // resumes as a plain single-chain run
        c.chains = 3;
        c.until = "rhat<1.01".into();
        c.trace_out = "elsewhere/trace.json".into();
        // the transport moves bytes, never bits: a checkpoint written
        // in-process must resume over sockets (and vice versa)
        c.transport = "uds".into();
        c.listen = "/tmp/pibp.sock".into();
        assert_eq!(c.fingerprint(), base.fingerprint());
        // chain-relevant keys MUST change it
        let mut c = base.clone();
        c.seed = 1;
        assert_ne!(c.fingerprint(), base.fingerprint());
        let mut c = base.clone();
        c.processors = 4;
        assert_ne!(c.fingerprint(), base.fingerprint());
        let mut c = base.clone();
        c.eval_every = 7;
        assert_ne!(c.fingerprint(), base.fingerprint());
    }

    #[test]
    fn checkpoint_keys_require_hybrid_and_trace_thin_positive() {
        let mut c = RunConfig::default();
        c.checkpoint_every = 5;
        assert!(c.validate().is_ok());
        c.sampler = SamplerKind::Collapsed;
        assert!(c.validate().is_err());
        c.checkpoint_every = 0;
        c.keep_samples = 4;
        assert!(c.validate().is_err());
        c.sampler = SamplerKind::Hybrid;
        assert!(c.validate().is_ok());
        c.trace_thin = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn diag_keys_validate() {
        let mut c = RunConfig::default();
        c.apply("chains", "0").unwrap();
        assert_eq!(c.chains, 1, "chains clamps like threads");
        c.chains = 3;
        c.until = "rhat<1.05".into();
        assert!(c.validate().is_ok());
        c.until = "nonsense".into();
        assert!(c.validate().is_err(), "malformed stop rule rejected early");
        c.until.clear();
        c.sampler = SamplerKind::Collapsed;
        assert!(c.validate().is_err(), "chains > 1 requires hybrid");
        c.chains = 1;
        c.until = "ess>10".into();
        assert!(c.validate().is_err(), "until requires hybrid");
        c.sampler = SamplerKind::Hybrid;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn transport_keys_validate() {
        let mut c = RunConfig::default();
        assert!(c.validate().is_ok(), "channel default validates");
        c.apply("transport", "uds").unwrap();
        assert!(c.validate().is_err(), "uds without listen rejected");
        c.apply("listen", "/tmp/pibp_validate.sock").unwrap();
        assert!(c.validate().is_ok());
        c.apply("transport", "tcp").unwrap();
        c.apply("listen", "127.0.0.1:9001").unwrap();
        assert!(c.validate().is_ok());
        c.apply("transport", "mpi").unwrap();
        assert!(c.validate().is_err(), "unknown transport rejected");
        // a listen address with transport=channel is a likely typo
        c.apply("transport", "channel").unwrap();
        assert!(c.validate().is_err(), "channel + listen rejected");
        c.apply("listen", "").unwrap();
        assert!(c.validate().is_ok());
        // sockets require the hybrid sampler and a single chain
        c.apply("transport", "tcp").unwrap();
        c.apply("listen", "127.0.0.1:9001").unwrap();
        c.sampler = SamplerKind::Collapsed;
        assert!(c.validate().is_err(), "sockets require hybrid");
        c.sampler = SamplerKind::Hybrid;
        c.chains = 3;
        assert!(c.validate().is_err(), "sockets require chains=1");
        c.chains = 1;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn comm_cost_model() {
        let m = CommModel::default();
        let t = m.cost(1024 * 1024);
        assert!(t > 50e-6 && t < 2e-3, "t={t}");
    }
}
