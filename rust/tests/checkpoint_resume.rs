//! Durable-state exactness: a chain checkpointed at iteration t and
//! resumed must be **bit-identical** to one that never stopped — for
//! every (P, T) in the tested grid — and posterior queries answered from
//! a checkpoint file must match the same queries answered from the
//! in-process sample reservoir.

use std::path::{Path, PathBuf};

use pibp::config::{Backend, CommModel, RunConfig, SamplerKind};
use pibp::coordinator::{Coordinator, CoordinatorConfig};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::model::missing::{missing_mse, Mask};
use pibp::model::state::Kernel;
use pibp::model::LinGauss;
use pibp::rng::Pcg64;
use pibp::runner;
use pibp::samplers::SamplerOptions;
use pibp::serve::PredictEngine;
use pibp::snapshot::Checkpoint;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pibp_ckpt_it_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn coord_cfg(p: usize, t: usize, seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        processors: p,
        sub_iters: 5,
        threads_per_worker: t,
        kernel: Kernel::Scalar,
        seed,
        lg: LinGauss::new(0.5, 1.0),
        alpha: 1.0,
        // production options — demotion ON, so the snapshot must carry
        // the full demote/promote pipeline state
        opts: SamplerOptions::default(),
        backend: Backend::Native,
        artifacts_dir: Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        comm: CommModel::default(),
        ..Default::default()
    }
}

/// Coordinator-level: snapshot mid-chain, restore into a *fresh*
/// coordinator, and require every subsequent iteration (and the gathered
/// Z) to match the original bit-for-bit across the (P, T) grid.
#[test]
fn coordinator_snapshot_restore_is_bit_exact_across_p_t_grid() {
    let (ds, _) = generate(&CambridgeConfig { n: 160, seed: 5, ..Default::default() });
    for p in [1usize, 4] {
        for t in [1usize, 4] {
            let mut a = Coordinator::new(&ds.x, coord_cfg(p, t, 31)).unwrap();
            for _ in 0..5 {
                a.step().unwrap();
            }
            let snap = a.snapshot().unwrap();
            assert_eq!(snap.iter, 5);
            assert_eq!(snap.workers.len(), p);
            // original continues
            let mut pins = Vec::new();
            for _ in 0..5 {
                let rec = a.step().unwrap();
                pins.push((
                    rec.k,
                    rec.alpha.to_bits(),
                    rec.sigma_x.to_bits(),
                    rec.sigma_a.to_bits(),
                ));
            }
            let z_a = a.gather_z().unwrap();
            let pi_a: Vec<u64> = a.params().pi.iter().map(|v| v.to_bits()).collect();

            // fresh coordinator, restored, must replay identically
            let mut b = Coordinator::new(&ds.x, coord_cfg(p, t, 31)).unwrap();
            b.restore(&snap).unwrap();
            for (it, pin) in pins.iter().enumerate() {
                let rec = b.step().unwrap();
                assert_eq!(
                    (
                        rec.k,
                        rec.alpha.to_bits(),
                        rec.sigma_x.to_bits(),
                        rec.sigma_a.to_bits()
                    ),
                    *pin,
                    "P={p} T={t}: iteration {it} after restore diverged"
                );
            }
            let z_b = b.gather_z().unwrap();
            assert_eq!(z_a, z_b, "P={p} T={t}: gathered Z diverged after restore");
            let pi_b: Vec<u64> = b.params().pi.iter().map(|v| v.to_bits()).collect();
            assert_eq!(pi_a, pi_b, "P={p} T={t}: π diverged after restore");
            assert!(
                a.params().a.max_abs_diff(&b.params().a) == 0.0,
                "P={p} T={t}: loadings A diverged after restore"
            );
            assert!(z_a.k() > 0, "P={p} T={t}: chain never instantiated a feature");
        }
    }
}

/// Restoring a snapshot into a coordinator with a different processor
/// count must be rejected, not silently mangled.
#[test]
fn restore_rejects_mismatched_processor_count() {
    let (ds, _) = generate(&CambridgeConfig { n: 60, seed: 2, ..Default::default() });
    let mut a = Coordinator::new(&ds.x, coord_cfg(2, 1, 3)).unwrap();
    a.step().unwrap();
    let snap = a.snapshot().unwrap();
    let mut b = Coordinator::new(&ds.x, coord_cfg(3, 1, 3)).unwrap();
    let err = b.restore(&snap).unwrap_err().to_string();
    assert!(err.contains("workers"), "unexpected error: {err}");
}

fn run_cfg(p: usize, t: usize, dir: &Path) -> RunConfig {
    RunConfig {
        n: 120,
        iters: 10,
        eval_every: 3,
        sampler: SamplerKind::Hybrid,
        processors: p,
        threads_per_worker: t,
        seed: 41,
        keep_samples: 16,
        out_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    }
}

/// Full-stack acceptance: run 10 iterations uninterrupted; run 5
/// iterations with checkpointing, then `runner::resume` to 10 from the
/// file. α / σ / π / A / Z (via the per-iteration reservoir samples) and
/// the held-out trace must agree bit-for-bit, for every (P, T).
#[test]
fn resume_from_file_matches_uninterrupted_run_across_p_t_grid() {
    for p in [1usize, 4] {
        for t in [1usize, 4] {
            let dir = tmp_dir(&format!("resume_{p}_{t}"));
            let ckpt = dir.join("state.pibp");

            // uninterrupted reference (no checkpointing at all)
            let full = runner::run(&run_cfg(p, t, &dir), |_| {}).unwrap();

            // interrupted segment: same chain, stop at 5, checkpoint at 5
            let mut part_cfg = run_cfg(p, t, &dir);
            part_cfg.iters = 5;
            part_cfg.checkpoint_every = 5;
            part_cfg.checkpoint_path = ckpt.to_string_lossy().into_owned();
            runner::run(&part_cfg, |_| {}).unwrap();

            // resume to the full horizon from the file
            let overrides = vec![("iters".to_string(), "10".to_string())];
            let (_, resumed) = runner::resume(&ckpt, &overrides, |_| {}).unwrap();

            // ---- final global parameters, bit-level ----
            let (fa, ra) = (&full.final_params, &resumed.final_params);
            assert_eq!(fa.k(), ra.k(), "P={p} T={t}: K diverged");
            assert_eq!(
                fa.alpha.to_bits(),
                ra.alpha.to_bits(),
                "P={p} T={t}: alpha diverged"
            );
            assert_eq!(
                fa.lg.sigma_x.to_bits(),
                ra.lg.sigma_x.to_bits(),
                "P={p} T={t}: sigma_x diverged"
            );
            assert_eq!(
                fa.lg.sigma_a.to_bits(),
                ra.lg.sigma_a.to_bits(),
                "P={p} T={t}: sigma_a diverged"
            );
            let pi_f: Vec<u64> = fa.pi.iter().map(|v| v.to_bits()).collect();
            let pi_r: Vec<u64> = ra.pi.iter().map(|v| v.to_bits()).collect();
            assert_eq!(pi_f, pi_r, "P={p} T={t}: π diverged");
            assert!(
                fa.a.max_abs_diff(&ra.a) == 0.0,
                "P={p} T={t}: loadings A diverged"
            );

            // ---- Z at every recorded iteration, via reservoir samples ----
            assert_eq!(
                full.reservoir.samples().len(),
                resumed.reservoir.samples().len(),
                "P={p} T={t}: reservoir sizes diverged"
            );
            for (sf, sr) in full
                .reservoir
                .samples()
                .iter()
                .zip(resumed.reservoir.samples())
            {
                assert_eq!(sf.iter, sr.iter, "P={p} T={t}: sample iters diverged");
                assert_eq!(sf.z, sr.z, "P={p} T={t}: Z at iter {} diverged", sf.iter);
                assert!(
                    sf.a.max_abs_diff(&sr.a) == 0.0,
                    "P={p} T={t}: sample A at iter {} diverged",
                    sf.iter
                );
                assert_eq!(
                    sf.sigma_x.to_bits(),
                    sr.sigma_x.to_bits(),
                    "P={p} T={t}: sample σx diverged"
                );
            }
            assert!(full.final_k > 0, "P={p} T={t}: chain never grew a feature");

            // ---- held-out trace: chain columns including the evaluated
            //      metric (the eval RNG stream is checkpointed too) ----
            assert_eq!(
                full.trace.points.len(),
                resumed.trace.points.len(),
                "P={p} T={t}: trace lengths diverged"
            );
            for (pf, pr) in full.trace.points.iter().zip(&resumed.trace.points) {
                assert_eq!(pf.iter, pr.iter, "P={p} T={t}: trace iters diverged");
                assert_eq!(pf.k, pr.k, "P={p} T={t}: trace K diverged");
                assert_eq!(
                    pf.heldout.to_bits(),
                    pr.heldout.to_bits(),
                    "P={p} T={t}: held-out metric at iter {} diverged",
                    pf.iter
                );
                assert_eq!(pf.sigma_x.to_bits(), pr.sigma_x.to_bits());
                assert_eq!(pf.alpha.to_bits(), pr.alpha.to_bits());
            }
        }
    }
}

/// Resuming under a configuration that changes the chain must be refused.
#[test]
fn resume_rejects_chain_relevant_overrides() {
    let dir = tmp_dir("reject");
    let ckpt = dir.join("reject.pibp");
    let mut cfg = run_cfg(1, 1, &dir);
    cfg.iters = 4;
    cfg.checkpoint_every = 2;
    cfg.checkpoint_path = ckpt.to_string_lossy().into_owned();
    runner::run(&cfg, |_| {}).unwrap();

    // chain-relevant override → fingerprint mismatch
    let bad = vec![
        ("iters".to_string(), "8".to_string()),
        ("seed".to_string(), "999".to_string()),
    ];
    let err = runner::resume(&ckpt, &bad, |_| {}).unwrap_err().to_string();
    assert!(err.contains("fingerprint"), "unexpected error: {err}");

    // already past the horizon → clear refusal
    let noop = vec![("iters".to_string(), "3".to_string())];
    let err = runner::resume(&ckpt, &noop, |_| {}).unwrap_err().to_string();
    assert!(err.contains("already"), "unexpected error: {err}");

    // benign overrides (threads, storage kernel) are fine
    let ok = vec![
        ("iters".to_string(), "6".to_string()),
        ("threads_per_worker".to_string(), "2".to_string()),
        ("kernel".to_string(), "packed".to_string()),
    ];
    runner::resume(&ckpt, &ok, |_| {}).unwrap();
}

/// The storage kernel is bit-invariant, so a checkpoint written under one
/// kernel must restore and continue bit-exactly under the other — pinned
/// against an uninterrupted scalar reference in both directions.
#[test]
fn resume_swaps_kernel_bit_exactly() {
    let (p, t) = (2usize, 2usize);
    let dir = tmp_dir("kernel_swap");

    // uninterrupted scalar reference chain
    let full = runner::run(&run_cfg(p, t, &dir), |_| {}).unwrap();
    assert!(full.final_k > 0, "reference chain never grew a feature");

    for (write_kernel, resume_kernel) in
        [(Kernel::Scalar, "packed"), (Kernel::Packed, "scalar")]
    {
        let tag = format!("{}→{}", write_kernel.name(), resume_kernel);
        let ckpt = dir.join(format!("swap_{}.pibp", write_kernel.name()));
        let mut part = run_cfg(p, t, &dir);
        part.kernel = write_kernel;
        part.iters = 5;
        part.checkpoint_every = 5;
        part.checkpoint_path = ckpt.to_string_lossy().into_owned();
        runner::run(&part, |_| {}).unwrap();

        let overrides = vec![
            ("iters".to_string(), "10".to_string()),
            ("kernel".to_string(), resume_kernel.to_string()),
        ];
        let (_, resumed) = runner::resume(&ckpt, &overrides, |_| {}).unwrap();

        let (fa, ra) = (&full.final_params, &resumed.final_params);
        assert_eq!(fa.k(), ra.k(), "{tag}: K diverged");
        assert_eq!(fa.alpha.to_bits(), ra.alpha.to_bits(), "{tag}: alpha diverged");
        assert_eq!(
            fa.lg.sigma_x.to_bits(),
            ra.lg.sigma_x.to_bits(),
            "{tag}: sigma_x diverged"
        );
        assert_eq!(
            fa.lg.sigma_a.to_bits(),
            ra.lg.sigma_a.to_bits(),
            "{tag}: sigma_a diverged"
        );
        let pi_f: Vec<u64> = fa.pi.iter().map(|v| v.to_bits()).collect();
        let pi_r: Vec<u64> = ra.pi.iter().map(|v| v.to_bits()).collect();
        assert_eq!(pi_f, pi_r, "{tag}: π diverged");
        assert!(fa.a.max_abs_diff(&ra.a) == 0.0, "{tag}: loadings A diverged");
        assert_eq!(
            full.reservoir.samples(),
            resumed.reservoir.samples(),
            "{tag}: reservoir samples diverged"
        );
        assert_eq!(
            full.trace.points.len(),
            resumed.trace.points.len(),
            "{tag}: trace lengths diverged"
        );
        for (pf, pr) in full.trace.points.iter().zip(&resumed.trace.points) {
            assert_eq!(pf.k, pr.k, "{tag}: trace K at iter {} diverged", pf.iter);
            assert_eq!(
                pf.heldout.to_bits(),
                pr.heldout.to_bits(),
                "{tag}: held-out metric at iter {} diverged",
                pf.iter
            );
        }
    }
}

/// Acceptance: `pibp predict`-style queries answered from a *loaded*
/// checkpoint must match the same queries answered from the in-process
/// reservoir of the run that wrote it — including the imputation MSE —
/// and must be invariant to the predict thread count.
#[test]
fn predict_from_checkpoint_matches_in_process_computation() {
    let dir = tmp_dir("predict");
    let ckpt_path = dir.join("predict.pibp");
    let mut cfg = run_cfg(2, 1, &dir);
    cfg.iters = 8;
    cfg.keep_samples = 6;
    cfg.checkpoint_every = 4;
    cfg.checkpoint_path = ckpt_path.to_string_lossy().into_owned();
    let out = runner::run(&cfg, |_| {}).unwrap();
    assert!(!out.reservoir.is_empty(), "run kept no samples");

    let ck = Checkpoint::load(&ckpt_path).unwrap();
    assert_eq!(
        ck.reservoir.samples().len(),
        out.reservoir.samples().len(),
        "checkpointed reservoir diverged from the in-process one"
    );
    for (a, b) in ck.reservoir.samples().iter().zip(out.reservoir.samples()) {
        assert_eq!(a, b, "sample at iter {} changed through the file", a.iter);
    }

    // the run's own held-out rows as the query batch
    let ds = runner::build_dataset(&cfg).unwrap();
    let (_, test) = ds.split_heldout(cfg.heldout_frac);
    let q = test.x;
    let mask = Mask::random(q.rows(), q.cols(), 0.3, &mut Pcg64::new(7).split(4242));

    let in_proc = PredictEngine::new(out.reservoir.samples(), 3, 1);
    let from_file = PredictEngine::new(ck.reservoir.samples(), 3, 4);

    let r1 = in_proc.impute(&q, &mask, 13);
    let r2 = from_file.impute(&q, &mask, 13);
    assert!(r1.max_abs_diff(&r2) == 0.0, "imputation diverged through the file");
    let mse1 = missing_mse(&q, &r1, &mask);
    let mse2 = missing_mse(&q, &r2, &mask);
    assert_eq!(mse1.to_bits(), mse2.to_bits(), "imputation MSE diverged");
    assert!(mse1.is_finite());

    let l1 = in_proc.heldout_loglik(&q, 13);
    let l2 = from_file.heldout_loglik(&q, 13);
    assert_eq!(l1.total.to_bits(), l2.total.to_bits(), "predictive loglik diverged");

    let d1 = in_proc.reconstruct(&q, 13);
    let d2 = from_file.reconstruct(&q, 13);
    assert!(d1.max_abs_diff(&d2) == 0.0, "reconstruction diverged");
}
