//! Convergence diagnostics must be provably non-perturbing, like `--obs`
//! (`obs_equivalence.rs`): replica chain `c` of a diagnosed
//! `runner::run_multi` is **bit-identical** to a standalone `runner::run`
//! of `replica_config(cfg, c)`, across the (C, P, T) grid. The streaming
//! estimators only *read* the trace points each chain keeps and draw no
//! RNG, so the chain cannot tell it is being diagnosed.
//!
//! On top of the bit-identity pin, this binary cross-checks the online
//! estimators against their batch references over the real sampler
//! output (relative error ≤ 1e-12 — see `metrics::online` for why
//! relative, and why the integer K series is excluded), and pins the
//! determinism of `--until` early stopping: the trigger iteration is
//! reproducible, and the stopped chains equal a standalone run with
//! `iters = stopped_at`.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use pibp::config::{RunConfig, SamplerKind};
use pibp::metrics::{ess, split_rhat, DIAG_QUANTITIES};
use pibp::runner::{self, MultiOutcome, RunOutcome};

/// Serialises the tests in this binary: `run`/`run_multi` set the
/// process-global obs level/registry from the config.
static GATE: Mutex<()> = Mutex::new(());

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pibp_diag_eq_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_cfg(p: usize, t: usize, dir: &Path) -> RunConfig {
    RunConfig {
        n: 120,
        iters: 8,
        eval_every: 2,
        sampler: SamplerKind::Hybrid,
        processors: p,
        threads_per_worker: t,
        seed: 37,
        keep_samples: 8,
        out_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    }
}

/// Bit-level chain equality: global parameters, every reservoir sample,
/// and the held-out trace (chain columns only — never measured time).
fn assert_chains_identical(a: &RunOutcome, b: &RunOutcome, tag: &str) {
    let (fa, fb) = (&a.final_params, &b.final_params);
    assert_eq!(fa.k(), fb.k(), "{tag}: K diverged");
    assert_eq!(fa.alpha.to_bits(), fb.alpha.to_bits(), "{tag}: alpha diverged");
    assert_eq!(
        fa.lg.sigma_x.to_bits(),
        fb.lg.sigma_x.to_bits(),
        "{tag}: sigma_x diverged"
    );
    assert_eq!(
        fa.lg.sigma_a.to_bits(),
        fb.lg.sigma_a.to_bits(),
        "{tag}: sigma_a diverged"
    );
    let pi_a: Vec<u64> = fa.pi.iter().map(|v| v.to_bits()).collect();
    let pi_b: Vec<u64> = fb.pi.iter().map(|v| v.to_bits()).collect();
    assert_eq!(pi_a, pi_b, "{tag}: π diverged");
    assert!(fa.a.max_abs_diff(&fb.a) == 0.0, "{tag}: loadings A diverged");
    assert_eq!(
        a.reservoir.samples(),
        b.reservoir.samples(),
        "{tag}: reservoir samples diverged"
    );
    assert_eq!(
        a.trace.points.len(),
        b.trace.points.len(),
        "{tag}: trace lengths diverged"
    );
    for (pa, pb) in a.trace.points.iter().zip(&b.trace.points) {
        assert_eq!(pa.iter, pb.iter, "{tag}: trace iters diverged");
        assert_eq!(pa.k, pb.k, "{tag}: trace K at iter {} diverged", pa.iter);
        assert_eq!(
            pa.heldout.to_bits(),
            pb.heldout.to_bits(),
            "{tag}: held-out metric at iter {} diverged",
            pa.iter
        );
        assert_eq!(pa.sigma_x.to_bits(), pb.sigma_x.to_bits(), "{tag}: trace σx");
        assert_eq!(pa.alpha.to_bits(), pb.alpha.to_bits(), "{tag}: trace α");
    }
    assert!(a.final_k > 0, "{tag}: chain never grew a feature");
}

/// Relative error with an absolute floor, matching the online module's
/// own agreement tests (heldout sits at ~1e3 scale, ESS at ~1e0).
fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1.0)
}

/// The continuous per-quantity series of one chain's kept trace points,
/// in `DIAG_QUANTITIES` order (k excluded — its batch/online Geyer scans
/// may legitimately tie-break differently on integer data).
fn continuous_series(out: &RunOutcome) -> [Vec<f64>; 3] {
    [
        out.trace.points.iter().map(|p| p.heldout).collect(),
        out.trace.points.iter().map(|p| p.alpha).collect(),
        out.trace.points.iter().map(|p| p.sigma_x).collect(),
    ]
}

/// The tentpole guarantee: every replica chain of a diagnosed run is
/// bit-identical to the same-seed standalone run, for C ∈ {1, 3} across
/// the (P, T) grid. C=1 additionally pins that `chain_seed(s, 0) == s`:
/// a one-chain diagnosed run IS the plain run.
#[test]
fn replica_chains_match_standalone_runs_across_grid() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    for c_total in [1usize, 3] {
        for p in [1usize, 4] {
            for t in [1usize, 4] {
                let dir = tmp_dir(&format!("grid_{c_total}_{p}_{t}"));
                let mut cfg = run_cfg(p, t, &dir);
                cfg.chains = c_total;
                // chains=1 without an until rule must route through run();
                // give it a rule that can never fire so run_multi accepts
                // the config and still runs the full horizon
                if c_total == 1 {
                    cfg.until = "ess>1000000".into();
                }
                let mout = runner::run_multi(&cfg, |_| {}).unwrap();
                assert_eq!(mout.chains.len(), c_total);
                assert!(mout.diag.stopped_at.is_none(), "C={c_total}: rule fired?");
                for (idx, chain) in mout.chains.iter().enumerate() {
                    let solo_cfg = runner::replica_config(&cfg, idx);
                    assert_eq!(solo_cfg.seed, runner::chain_seed(cfg.seed, idx));
                    let solo = runner::run(&solo_cfg, |_| {}).unwrap();
                    assert_chains_identical(
                        chain,
                        &solo,
                        &format!("C={c_total} P={p} T={t} chain={idx}"),
                    );
                }
            }
        }
    }
}

/// The streaming estimators agree with the batch references on the real
/// sampler output: per-chain online ESS vs `metrics::ess`, cross-chain
/// online split-R̂ vs `metrics::split_rhat`, at ≤ 1e-12 relative error
/// over the continuous quantities.
#[test]
fn online_estimators_match_batch_on_sampler_output() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let dir = tmp_dir("online_vs_batch");
    let mut cfg = run_cfg(2, 2, &dir);
    cfg.chains = 3;
    cfg.iters = 12;
    cfg.eval_every = 1;
    let mout: MultiOutcome = runner::run_multi(&cfg, |_| {}).unwrap();
    let per_chain: Vec<[Vec<f64>; 3]> =
        mout.chains.iter().map(continuous_series).collect();
    for q in 0..3 {
        let name = DIAG_QUANTITIES[q];
        let chains_q: Vec<Vec<f64>> =
            per_chain.iter().map(|s| s[q].clone()).collect();
        let batch_rhat = split_rhat(&chains_q);
        let online_rhat = mout.diag.rhat[q];
        if batch_rhat.is_finite() {
            assert!(
                rel_err(online_rhat, batch_rhat) <= 1e-12,
                "{name}: online R̂ {online_rhat} vs batch {batch_rhat}"
            );
        } else {
            assert!(
                !online_rhat.is_finite(),
                "{name}: online R̂ finite ({online_rhat}) where batch is {batch_rhat}"
            );
        }
        for (c, series) in chains_q.iter().enumerate() {
            // a constant series is degenerate for the online estimator
            // and pins to a small batch value; skip like the gates do
            if series.iter().all(|v| *v == series[0]) {
                continue;
            }
            let batch = ess(series);
            let online = mout.diag.ess[q][c];
            assert!(
                rel_err(online, batch) <= 1e-12,
                "{name} chain {c}: online ESS {online} vs batch {batch}"
            );
        }
    }
    // the summary saw exactly the kept trace points, nothing else
    assert_eq!(mout.diag.points, mout.chains[0].trace.points.len());
}

/// `--until` early stopping is deterministic and non-perturbing: the
/// trigger iteration is identical on a rerun, and every stopped chain is
/// bit-identical to a standalone run with `iters = stopped_at`.
#[test]
fn early_stop_is_reproducible_and_matches_shorter_standalone() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let dir = tmp_dir("early_stop");
    let mut cfg = run_cfg(2, 1, &dir);
    cfg.chains = 2;
    cfg.iters = 12;
    cfg.eval_every = 1;
    // fires as soon as MIN_STOP_POINTS kept points exist (rhat omitted:
    // a 4-point split-R̂ of the integer K series may be non-finite)
    cfg.until = "ess>0.5".into();
    let first = runner::run_multi(&cfg, |_| {}).unwrap();
    let stopped = first.diag.stopped_at.expect("rule should have fired");
    assert!(stopped < cfg.iters, "rule fired only at the horizon");

    let second = runner::run_multi(&cfg, |_| {}).unwrap();
    assert_eq!(second.diag.stopped_at, Some(stopped), "trigger not reproducible");

    for (idx, chain) in first.chains.iter().enumerate() {
        let mut solo_cfg = runner::replica_config(&cfg, idx);
        solo_cfg.iters = stopped;
        let solo = runner::run(&solo_cfg, |_| {}).unwrap();
        assert_chains_identical(chain, &solo, &format!("early-stop chain={idx}"));
    }
}

/// A rule that never fires changes nothing: the run is bit-identical to
/// the same multi-chain run with no rule at all, and records no trigger.
#[test]
fn never_firing_rule_is_inert() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let dir = tmp_dir("inert_rule");
    let mut base = run_cfg(1, 1, &dir);
    base.chains = 2;
    let plain = runner::run_multi(&base, |_| {}).unwrap();
    let mut ruled_cfg = base.clone();
    ruled_cfg.until = "ess>1000000".into();
    let ruled = runner::run_multi(&ruled_cfg, |_| {}).unwrap();
    assert!(plain.diag.stopped_at.is_none() && ruled.diag.stopped_at.is_none());
    for (idx, (a, b)) in plain.chains.iter().zip(&ruled.chains).enumerate() {
        assert_chains_identical(a, b, &format!("inert-rule chain={idx}"));
    }
}
