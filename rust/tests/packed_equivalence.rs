//! Differential harness pinning the packed (u64-word, popcount) Z
//! kernel to the scalar (byte-per-bit) one.
//!
//! Two layers:
//! 1. propcheck suites driving random op sequences through a packed and
//!    a scalar [`FeatureState`] in lockstep, asserting bit-equality of
//!    the bits, the column counts, and the popcount gram against a
//!    dense ZᵀZ after every step;
//! 2. full-sweep differential cases pinning `par_sweep_rows` on packed
//!    states against scalar — Z bits, residual bytes, flip counts and
//!    the parent RNG stream — over a seed × K × T grid.

use pibp::linalg::Mat;
use pibp::model::state::{FeatureState, Kernel};
use pibp::parallel::{par_sweep_rows, ExecConfig, ParallelCtx};
use pibp::propcheck::{self, Gen};
use pibp::rng::Pcg64;
use pibp::samplers::uncollapsed::residuals;
use pibp::testutil::sweep_problem;

fn mat_bits(m: &Mat) -> Vec<u64> {
    m.as_slice().iter().map(|v| v.to_bits()).collect()
}

/// Every cross-repr invariant the pair must satisfy after each op.
fn assert_lockstep(scalar: &FeatureState, packed: &FeatureState, ctx: &str) -> Result<(), String> {
    if !scalar.check_invariants() {
        return Err(format!("{ctx}: scalar invariants broken"));
    }
    if !packed.check_invariants() {
        return Err(format!("{ctx}: packed invariants broken"));
    }
    if packed.k() > 0 && !packed.is_packed() {
        return Err(format!("{ctx}: packed state silently became scalar"));
    }
    if scalar != packed {
        return Err(format!("{ctx}: Z bits diverged (k={})", scalar.k()));
    }
    if scalar.m() != packed.m() {
        return Err(format!("{ctx}: column counts diverged"));
    }
    // popcount gram must be bit-identical to the dense ZᵀZ of either repr
    let dense = scalar.to_mat().gram();
    if mat_bits(&packed.gram()) != mat_bits(&dense) {
        return Err(format!("{ctx}: packed gram != dense ZᵀZ"));
    }
    if mat_bits(&scalar.gram()) != mat_bits(&dense) {
        return Err(format!("{ctx}: scalar gram != dense ZᵀZ"));
    }
    Ok(())
}

/// Flip bit (i, j) through the raw storage (not `set`), returning the
/// m-delta the caller owes `apply_m_delta` — the sweep kernels' access
/// pattern, exercised here against both layouts.
fn raw_flip(st: &mut FeatureState, i: usize, j: usize) -> i64 {
    let was_set = st.get(i, j) == 1;
    if st.is_packed() {
        let words = st.rows_words_mut(i..i + 1);
        words[j / 64] ^= 1u64 << (j % 64);
    } else {
        let bits = st.rows_bits_mut(i..i + 1);
        bits[j] ^= 1;
    }
    if was_set {
        -1
    } else {
        1
    }
}

#[test]
fn random_op_sequences_stay_bit_identical() {
    propcheck::run("packed/scalar op lockstep", 200, |g: &mut Gen| {
        let n = g.usize_in(1, 16);
        // spans 0, sub-word, exact-word and multi-word feature counts
        let k0 = g.usize_in(0, 80);
        let mut scalar = FeatureState::empty(n);
        let mut packed = FeatureState::empty_with(n, Kernel::Packed);
        scalar.add_features(k0);
        packed.add_features(k0);
        assert_lockstep(&scalar, &packed, "init")?;
        let ops = g.usize_in(1, 30);
        for step in 0..ops {
            let k = scalar.k();
            match *g.choose(&["set", "get", "row", "add", "compact", "raw", "tmm"]) {
                "set" if k > 0 => {
                    let (i, j) = (g.usize_in(0, n - 1), g.usize_in(0, k - 1));
                    let v = u8::from(g.bool(0.5));
                    scalar.set(i, j, v);
                    packed.set(i, j, v);
                }
                "get" if k > 0 => {
                    let (i, j) = (g.usize_in(0, n - 1), g.usize_in(0, k - 1));
                    if scalar.get(i, j) != packed.get(i, j) {
                        return Err(format!("step {step}: get({i},{j}) diverged"));
                    }
                }
                "row" if k > 0 => {
                    let i = g.usize_in(0, n - 1);
                    if scalar.row_f64(i) != packed.row_f64(i) {
                        return Err(format!("step {step}: row_f64({i}) diverged"));
                    }
                }
                "add" => {
                    let grow = g.usize_in(0, 9);
                    let ks = scalar.add_features(grow);
                    let kp = packed.add_features(grow);
                    if ks != kp {
                        return Err(format!("step {step}: add_features returned {ks} vs {kp}"));
                    }
                }
                "compact" => {
                    let keep_s = scalar.compact();
                    let keep_p = packed.compact();
                    if keep_s != keep_p {
                        return Err(format!("step {step}: compact keep lists diverged"));
                    }
                }
                "raw" if k > 0 => {
                    // raw-storage flip + apply_m_delta: the sweep kernels'
                    // write path
                    let (i, j) = (g.usize_in(0, n - 1), g.usize_in(0, k - 1));
                    let mut delta = vec![0i64; k];
                    delta[j] = raw_flip(&mut scalar, i, j);
                    let dp = raw_flip(&mut packed, i, j);
                    if delta[j] != dp {
                        return Err(format!("step {step}: raw flip deltas diverged"));
                    }
                    scalar.apply_m_delta(&delta);
                    packed.apply_m_delta(&delta);
                    if scalar.recount() != *scalar.m() || packed.recount() != *packed.m() {
                        return Err(format!("step {step}: m drifted from recount"));
                    }
                }
                "tmm" => {
                    let d = g.usize_in(1, 4);
                    let mut vals = vec![0.0f64; n * d];
                    for v in vals.iter_mut() {
                        *v = g.f64_in(-2.0, 2.0);
                    }
                    let x = Mat::from_fn(n, d, |i, j| vals[i * d + j]);
                    let dense = scalar.to_mat().t_matmul(&x);
                    if mat_bits(&packed.t_matmul(&x)) != mat_bits(&dense)
                        || mat_bits(&scalar.t_matmul(&x)) != mat_bits(&dense)
                    {
                        return Err(format!("step {step}: t_matmul != dense ZᵀX"));
                    }
                }
                _ => {} // op not applicable at k == 0
            }
            assert_lockstep(&scalar, &packed, &format!("step {step}"))?;
        }
        Ok(())
    });
}

#[test]
fn gram_matches_dense_on_random_matrices() {
    propcheck::run("popcount gram vs dense ZᵀZ", 200, |g: &mut Gen| {
        let n = g.usize_in(1, 24);
        let k = g.usize_in(1, 130); // up to three words per row
        let density = g.f64_in(0.05, 0.95);
        let mut packed = FeatureState::empty_with(n, Kernel::Packed);
        packed.add_features(k);
        for i in 0..n {
            for j in 0..k {
                if g.bool(density) {
                    packed.set(i, j, 1);
                }
            }
        }
        let mut scalar = packed.clone();
        scalar.set_kernel(Kernel::Scalar);
        assert_lockstep(&scalar, &packed, "built")?;
        // range variants must agree with the dense slice too
        let lo = g.usize_in(0, n - 1);
        let hi = g.usize_in(lo, n);
        let zm = packed.to_mat();
        let dense_range =
            Mat::from_fn(hi - lo, k, |i, j| zm.as_slice()[(lo + i) * k + j]).gram();
        if mat_bits(&packed.gram_range(lo..hi)) != mat_bits(&dense_range) {
            return Err(format!("gram_range({lo}..{hi}) != dense"));
        }
        if mat_bits(&scalar.gram_range(lo..hi)) != mat_bits(&dense_range) {
            return Err(format!("scalar gram_range({lo}..{hi}) != dense"));
        }
        Ok(())
    });
}

/// One full sweep on each kernel; returns everything the chain contract
/// pins: final Z, residual bytes, flip count, and the parent RNG's next
/// draw (stream position).
fn sweep_once(
    kernel: Kernel,
    threads: usize,
    n: usize,
    k: usize,
    d: usize,
    seed: u64,
) -> (FeatureState, Vec<u64>, usize, u64) {
    let (x, mut z, a, logit) = sweep_problem(n, k, d, seed);
    z.set_kernel(kernel);
    let mut resid = residuals(&x, &z, &a, 0..n);
    let exec = ExecConfig {
        ctx: if threads <= 1 { ParallelCtx::inline() } else { ParallelCtx::pooled(threads) },
        block_rows: 7, // ragged last block on purpose
        kernel,
    };
    let mut rng = Pcg64::new(seed ^ 0xabcd);
    let mut flips = 0;
    for _ in 0..3 {
        flips += par_sweep_rows(&mut z, &mut resid, &a, &logit, 2.0, 0..n, k, &exec, &mut rng);
    }
    (z, mat_bits(&resid), flips, rng.next_u64())
}

#[test]
fn full_sweeps_match_scalar_over_seed_grid() {
    // K spans sub-word, exact-word and multi-word rows; T spans inline
    // and pooled scheduling. Scalar at T=1 is the pinned oracle.
    for &(n, k, d) in &[(23usize, 5usize, 3usize), (16, 64, 4), (31, 70, 2)] {
        for seed in 0..4u64 {
            let (z0, r0, f0, s0) = sweep_once(Kernel::Scalar, 1, n, k, d, seed);
            for &t in &[1usize, 2, 4] {
                for &kernel in &[Kernel::Scalar, Kernel::Packed] {
                    let (z, r, f, s) = sweep_once(kernel, t, n, k, d, seed);
                    let tag = format!("n={n} k={k} seed={seed} T={t} {:?}", kernel);
                    assert_eq!(z, z0, "Z diverged [{tag}]");
                    assert_eq!(r, r0, "residual bytes diverged [{tag}]");
                    assert_eq!(f, f0, "flip count diverged [{tag}]");
                    assert_eq!(s, s0, "parent RNG stream diverged [{tag}]");
                    if kernel == Kernel::Packed {
                        assert!(z.is_packed(), "sweep changed the repr [{tag}]");
                    }
                }
            }
        }
    }
}
