//! Transport invariance, the headline guarantee of the socket transports:
//! a P-worker run whose workers are **separate `pibp worker --connect`
//! processes over a Unix domain socket** is bit-identical to the same run
//! with in-process channel workers — global parameters (α, σ, π, A), the
//! gathered Z, and the held-out trace, on both Z kernels.
//!
//! Workers are real child processes of the test binary (the `pibp` CLI
//! itself, via `CARGO_BIN_EXE_pibp`), so the whole path is exercised:
//! CLI parse → connect retry → versioned handshake → SETUP decode →
//! worker loop over framed sockets.
//!
//! Also pinned here: a worker process killed mid-run surfaces as a
//! contextual error within the transport's bounded timeouts — never a
//! hung gather.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;

use pibp::config::{Backend, CommModel, RunConfig, SamplerKind};
use pibp::coordinator::{Coordinator, CoordinatorConfig, TransportConfig};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::linalg::Mat;
use pibp::model::state::Kernel;
use pibp::model::LinGauss;
use pibp::runner::{self, RunOutcome};
use pibp::samplers::SamplerOptions;

/// Serialises the runner-level test against the others: `runner::run`
/// sets the process-global obs level/registry.
static GATE: Mutex<()> = Mutex::new(());

/// A per-test UDS path that is short (sockaddr_un limit), unique across
/// concurrent test processes, and stale-free.
fn sock_path(tag: &str) -> String {
    let p = std::env::temp_dir().join(format!("pibp_pe_{}_{tag}.sock", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p.to_string_lossy().into_owned()
}

/// Launch `n` real `pibp worker --connect` child processes. They retry
/// the connect with the transport's bounded backoff, so spawning before
/// the master binds is fine (and is exactly the CI `dist-smoke` order).
fn spawn_workers(addr: &str, n: usize) -> Vec<Child> {
    (0..n)
        .map(|i| {
            Command::new(env!("CARGO_BIN_EXE_pibp"))
                .args(["worker", "--connect", addr])
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()
                .unwrap_or_else(|e| panic!("spawning pibp worker {i}: {e}"))
        })
        .collect()
}

/// Reap children without risking a hung test: poll for ~10s, then kill.
/// A healthy run has already sent Shutdown by the time this is called, so
/// the kill branch firing would itself be a protocol bug.
fn reap(children: Vec<Child>) {
    for mut c in children {
        let mut done = false;
        for _ in 0..400 {
            if c.try_wait().expect("try_wait").is_some() {
                done = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
        if !done {
            c.kill().ok();
            panic!("worker process did not exit after Shutdown");
        }
    }
}

fn coord_cfg(p: usize, kernel: Kernel, seed: u64, transport: TransportConfig) -> CoordinatorConfig {
    CoordinatorConfig {
        processors: p,
        sub_iters: 5,
        threads_per_worker: 1,
        kernel,
        seed,
        lg: LinGauss::new(0.5, 1.0),
        alpha: 1.0,
        opts: SamplerOptions::default(),
        backend: Backend::Native,
        artifacts_dir: Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        comm: CommModel::default(),
        transport,
    }
}

/// Everything the master samples, bit-level, after one global iteration.
#[derive(PartialEq, Debug)]
struct IterPin {
    k: usize,
    alpha: u64,
    sigma_x: u64,
    sigma_a: u64,
    pi: Vec<u64>,
    a: Vec<u64>,
}

fn run_pinned(x: &Mat, cfg: CoordinatorConfig, iters: usize) -> (Vec<IterPin>, pibp::model::state::FeatureState) {
    let mut coord = Coordinator::new(x, cfg).expect("coordinator");
    let mut pins = Vec::with_capacity(iters);
    for _ in 0..iters {
        let rec = coord.step().expect("step");
        let gp = coord.params();
        pins.push(IterPin {
            k: rec.k,
            alpha: rec.alpha.to_bits(),
            sigma_x: rec.sigma_x.to_bits(),
            sigma_a: rec.sigma_a.to_bits(),
            pi: gp.pi.iter().map(|v| v.to_bits()).collect(),
            a: (0..gp.a.rows())
                .flat_map(|i| (0..gp.a.cols()).map(move |j| (i, j)))
                .map(|(i, j)| gp.a[(i, j)].to_bits())
                .collect(),
        });
    }
    let z = coord.gather_z().expect("gather_z");
    (pins, z)
}

/// The tentpole acceptance pin: P=4 over UDS (worker processes) is
/// bit-identical to P=4 in-process, on both Z kernels — α, σx, σa, π, A
/// every iteration, and the gathered Z at the end.
#[test]
fn p4_uds_worker_processes_match_in_process_channels_on_both_kernels() {
    let (ds, _) = generate(&CambridgeConfig { n: 96, seed: 2, ..Default::default() });
    for kernel in [Kernel::Scalar, Kernel::Packed] {
        let tag = format!("p4_{}", if kernel == Kernel::Packed { "pk" } else { "sc" });
        let (chan_pins, chan_z) =
            run_pinned(&ds.x, coord_cfg(4, kernel, 42, TransportConfig::Channel), 12);
        assert!(chan_pins.last().is_some_and(|p| p.k > 0), "{tag}: chain never grew a feature");

        let sock = sock_path(&tag);
        let workers = spawn_workers(&sock, 4);
        let (uds_pins, uds_z) = run_pinned(
            &ds.x,
            coord_cfg(4, kernel, 42, TransportConfig::Uds { listen: sock.clone() }),
            12,
        );
        reap(workers);

        assert_eq!(chan_pins.len(), uds_pins.len());
        for (it, (c, u)) in chan_pins.iter().zip(&uds_pins).enumerate() {
            assert_eq!(c, u, "{tag}: iteration {it} diverged across transports");
        }
        assert_eq!(chan_z, uds_z, "{tag}: gathered Z diverged across transports");
        assert!(!Path::new(&sock).exists(), "{tag}: shutdown left the UDS path behind");
    }
}

/// P=1 is the degenerate star — one worker process holding the whole
/// dataset. Same pins as the threaded run.
#[test]
fn p1_uds_worker_process_matches_in_process_channel() {
    let (ds, _) = generate(&CambridgeConfig { n: 60, seed: 3, ..Default::default() });
    let (chan_pins, chan_z) =
        run_pinned(&ds.x, coord_cfg(1, Kernel::Scalar, 7, TransportConfig::Channel), 10);

    let sock = sock_path("p1");
    let workers = spawn_workers(&sock, 1);
    let (uds_pins, uds_z) = run_pinned(
        &ds.x,
        coord_cfg(1, Kernel::Scalar, 7, TransportConfig::Uds { listen: sock }),
        10,
    );
    reap(workers);

    assert_eq!(chan_pins, uds_pins, "P=1 chain diverged across transports");
    assert_eq!(chan_z, uds_z, "P=1 gathered Z diverged across transports");
}

fn run_cfg(dir: &Path) -> RunConfig {
    RunConfig {
        n: 120,
        iters: 8,
        eval_every: 2,
        sampler: SamplerKind::Hybrid,
        processors: 4,
        seed: 37,
        out_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    }
}

fn assert_outcomes_identical(a: &RunOutcome, b: &RunOutcome, tag: &str) {
    let (fa, fb) = (&a.final_params, &b.final_params);
    assert_eq!(fa.k(), fb.k(), "{tag}: K diverged");
    assert_eq!(fa.alpha.to_bits(), fb.alpha.to_bits(), "{tag}: alpha diverged");
    assert_eq!(fa.lg.sigma_x.to_bits(), fb.lg.sigma_x.to_bits(), "{tag}: sigma_x diverged");
    let pi_a: Vec<u64> = fa.pi.iter().map(|v| v.to_bits()).collect();
    let pi_b: Vec<u64> = fb.pi.iter().map(|v| v.to_bits()).collect();
    assert_eq!(pi_a, pi_b, "{tag}: π diverged");
    assert!(fa.a.max_abs_diff(&fb.a) == 0.0, "{tag}: loadings A diverged");
    assert_eq!(a.trace.points.len(), b.trace.points.len(), "{tag}: trace lengths diverged");
    for (pa, pb) in a.trace.points.iter().zip(&b.trace.points) {
        assert_eq!(pa.iter, pb.iter, "{tag}: trace iters diverged");
        assert_eq!(pa.k, pb.k, "{tag}: trace K at iter {} diverged", pa.iter);
        assert_eq!(
            pa.heldout.to_bits(),
            pb.heldout.to_bits(),
            "{tag}: held-out metric at iter {} diverged",
            pa.iter
        );
        assert_eq!(pa.vtime_s.to_bits(), pb.vtime_s.to_bits(), "{tag}: vtime diverged");
    }
    assert!(a.final_k > 0, "{tag}: chain never grew a feature");
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pibp_proc_eq_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Full-stack pin through `runner::run` — config keys (`transport=uds`,
/// `listen=…`) down to the held-out trace and virtual time. Virtual time
/// matching bit-for-bit is the "VClock stays the vtime source" claim:
/// measured socket timing never leaks into the chain or its clock.
#[test]
fn runner_heldout_trace_is_transport_invariant() {
    let _g = GATE.lock().unwrap();
    let base = run_cfg(&tmp_dir("chan"));
    let chan = runner::run(&base, |_| {}).expect("channel run");

    let sock = sock_path("runner");
    let workers = spawn_workers(&sock, 4);
    let mut dist = run_cfg(&tmp_dir("uds"));
    dist.transport = "uds".into();
    dist.listen = sock;
    dist.validate().expect("distributed config validates");
    let uds = runner::run(&dist, |_| {}).expect("uds run");
    reap(workers);

    assert_outcomes_identical(&chan, &uds, "runner channel-vs-uds");
}

/// Failure semantics: a worker process killed mid-run must fail the
/// coordinator with a contextual error — within the transport's bounded
/// retries, not a hung gather. (The EOF on the dead worker's socket is
/// folded into the abort sentinel; the master's gather taxonomy names
/// the worker.)
#[test]
fn killed_worker_process_is_a_contextual_error_not_a_hang() {
    let (ds, _) = generate(&CambridgeConfig { n: 64, seed: 4, ..Default::default() });
    let sock = sock_path("kill");
    let mut workers = spawn_workers(&sock, 4);
    let mut coord = Coordinator::new(
        &ds.x,
        coord_cfg(4, Kernel::Scalar, 11, TransportConfig::Uds { listen: sock }),
    )
    .expect("coordinator");
    for _ in 0..3 {
        coord.step().expect("healthy step");
    }
    workers[2].kill().expect("kill worker 2");
    workers[2].wait().expect("reap killed worker");

    // The kill can land mid-iteration, so the *next* step may still
    // complete from buffered frames — but the error must arrive within a
    // couple of bounded steps, never a hang (the test harness itself is
    // the timeout of last resort).
    let mut err = None;
    for _ in 0..10 {
        match coord.step() {
            Ok(_) => continue,
            Err(e) => {
                err = Some(format!("{e:#}"));
                break;
            }
        }
    }
    let msg = err.expect("coordinator kept iterating with a dead worker process");
    assert!(
        msg.contains("worker"),
        "error should name the worker; got: {msg}"
    );
    drop(coord);
    workers.remove(2);
    for mut c in workers {
        // the master's shutdown already ran in drop(); survivors got the
        // Shutdown frame or a closed socket and must exit promptly
        let mut done = false;
        for _ in 0..400 {
            if c.try_wait().expect("try_wait").is_some() {
                done = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
        if !done {
            c.kill().ok();
            panic!("surviving worker hung after master shutdown");
        }
    }
}
