//! Integration: the PJRT runtime executing real AOT artifacts must agree
//! with the native-rust implementations of the same maths.
//!
//! Requires `artifacts/` (run `make artifacts`); every test is a no-op
//! skip if the manifest is absent so `cargo test` stays green on a fresh
//! clone.

use std::path::Path;

use pibp::linalg::Mat;
use pibp::model::state::FeatureState;
use pibp::rng::Pcg64;
use pibp::runtime::{Engine, Ops};
use pibp::samplers::uncollapsed::residuals;
use pibp::testutil::runtime_problem as problem;

fn engine() -> Option<Engine> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Engine::load(&dir).ok()
}

#[test]
fn suffstats_matches_native() {
    let Some(engine) = engine() else { return };
    let ops = Ops::new(&engine);
    let (x, z, _, _, _) = problem(300, 7, 36, 1);
    let (ztz, ztx) = ops.suffstats(&z, &x).unwrap();
    let zm = z.to_mat();
    let want_ztz = zm.gram();
    let want_ztx = zm.t_matmul(&x);
    assert!(ztz.max_abs_diff(&want_ztz) < 1e-2, "ztz diff");
    assert!(ztx.max_abs_diff(&want_ztx) < 1e-2, "ztx diff");
}

#[test]
fn suffstats_chunking_consistent() {
    let Some(engine) = engine() else { return };
    let ops = Ops::new(&engine);
    // 1500 rows forces a 1024 + 476 chunk split
    let (x, z, _, _, _) = problem(1500, 5, 36, 2);
    let (ztz, _) = ops.suffstats(&z, &x).unwrap();
    let want = z.to_mat().gram();
    assert!(ztz.max_abs_diff(&want) < 5e-2);
}

#[test]
fn zsweep_matches_native_probabilities() {
    let Some(engine) = engine() else { return };
    let ops = Ops::new(&engine);
    let (x, z0, a, pi, lg) = problem(200, 6, 36, 3);
    let prior_logit: Vec<f64> =
        pi.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
    let inv2s2 = 1.0 / (2.0 * lg.sigma_x * lg.sigma_x);

    // PJRT sweep with a recorded uniform stream
    let mut z_pjrt = z0.clone();
    let mut rng = Pcg64::new(42);
    let resid = ops
        .zsweep(&x, &mut z_pjrt, &a, &prior_logit, inv2s2, &mut rng)
        .unwrap();

    // replay the same uniforms through the native f64 recurrence and check
    // each decision where the uniform is not within f32 slop of the
    // boundary (kernel computes p1 in f32).
    let mut rng2 = Pcg64::new(42);
    let mut z_nat = z0.clone();
    let d = x.cols();
    let mut checked = 0usize;
    for n in 0..x.rows() {
        let mut r: Vec<f64> = x.row(n).to_vec();
        for kk in 0..z_nat.k() {
            if z_nat.get(n, kk) == 1 {
                for j in 0..d {
                    r[j] -= a[(kk, j)];
                }
            }
        }
        for kk in 0..z_nat.k() {
            let z_old = z_nat.get(n, kk);
            let mut r0a = 0.0;
            let mut aa = 0.0;
            for j in 0..d {
                let aj = a[(kk, j)];
                let r0 = r[j] + if z_old == 1 { aj } else { 0.0 };
                r0a += r0 * aj;
                aa += aj * aj;
            }
            let logit = prior_logit[kk] + (2.0 * r0a - aa) * inv2s2;
            let p1 = 1.0 / (1.0 + (-logit).exp());
            let u = rng2.uniform_f32() as f64;
            let bit = u8::from(u < p1);
            // adopt the PJRT decision to stay on its trajectory, but where
            // the margin is clear, the decisions must agree.
            let pjrt_bit = z_pjrt.get(n, kk);
            if (u - p1).abs() > 1e-3 {
                assert_eq!(bit, pjrt_bit, "row {n} k {kk}: u={u} p1={p1}");
                checked += 1;
            }
            let z_new = pjrt_bit;
            let delta = z_old as f64 - z_new as f64;
            if delta != 0.0 {
                for j in 0..d {
                    r[j] += delta * a[(kk, j)];
                }
                z_nat.set(n, kk, z_new);
            }
        }
    }
    assert!(checked > 800, "only {checked} clear-margin decisions checked");
    // returned residuals must equal X − Z_new A
    let want_resid = residuals(&x, &z_pjrt, &a, 0..x.rows());
    assert!(resid.max_abs_diff(&want_resid) < 1e-3);
    assert!(z_pjrt.check_invariants());
}

#[test]
fn zsweep_chunking_covers_all_rows() {
    let Some(engine) = engine() else { return };
    let ops = Ops::new(&engine);
    // strong pull-to-one prior: every bit in every chunk must flip on
    let (x, mut z, a, _, _) = problem(1100, 4, 36, 4);
    let mut rng = Pcg64::new(5);
    ops.zsweep(&x, &mut z, &a, &[60.0; 4], 0.0, &mut rng).unwrap();
    assert!(z.m().iter().all(|&m| m == 1100), "m={:?}", z.m());
}

#[test]
fn apost_matches_native_mean_and_distribution() {
    let Some(engine) = engine() else { return };
    let ops = Ops::new(&engine);
    let (x, z, _, _, lg) = problem(150, 5, 36, 6);
    let zm = z.to_mat();
    let ztz = zm.gram();
    let ztx = zm.t_matmul(&x);
    // with eps=0 is not exposed; check the MEAN by averaging draws
    let mut rng = Pcg64::new(7);
    let mut acc = Mat::zeros(5, 36);
    let reps = 200;
    for _ in 0..reps {
        acc.add_assign(&ops.apost(&ztz, &ztx, lg.sigma_x, lg.sigma_a, &mut rng).unwrap());
    }
    acc.scale(1.0 / reps as f64);
    let want = lg.apost_mean(&ztz, &ztx);
    assert!(acc.max_abs_diff(&want) < 0.05, "diff={}", acc.max_abs_diff(&want));
}

#[test]
fn heldout_matches_native() {
    let Some(engine) = engine() else { return };
    let ops = Ops::new(&engine);
    let (x, z, a, pi, lg) = problem(90, 6, 36, 8);
    let got = ops.heldout(&x, &z, &a, &pi, lg.sigma_x).unwrap();
    // native: gaussian + bernoulli prior
    let zm = z.to_mat();
    let ll = lg.loglik(&x, &zm, &a);
    let mut prior = 0.0;
    for (k, &p) in pi.iter().enumerate() {
        let mk = z.m()[k] as f64;
        prior += mk * p.ln() + (x.rows() as f64 - mk) * (1.0 - p).ln();
    }
    let want = ll + prior;
    assert!(
        (got - want).abs() < 0.05 * want.abs().max(10.0),
        "got {got}, want {want}"
    );
}

#[test]
fn collapsed_loglik_matches_native() {
    let Some(engine) = engine() else { return };
    let ops = Ops::new(&engine);
    let (x, z, _, _, lg) = problem(120, 5, 36, 9);
    let got = ops.collapsed_loglik(&x, &z, lg.sigma_x, lg.sigma_a).unwrap();
    let want = lg.collapsed_loglik(&x, &z.to_mat());
    assert!(
        (got - want).abs() < 0.02 * want.abs().max(10.0),
        "got {got}, want {want}"
    );
}

#[test]
fn executable_cache_reused() {
    let Some(engine) = engine() else { return };
    let ops = Ops::new(&engine);
    let (x, z, _, _, _) = problem(100, 4, 36, 10);
    ops.suffstats(&z, &x).unwrap();
    ops.suffstats(&z, &x).unwrap();
    ops.suffstats(&z, &x).unwrap();
    assert_eq!(engine.compiled_count(), 1, "recompiled instead of caching");
    assert_eq!(*engine.exec_count.borrow(), 3);
}

#[test]
fn empty_k_paths() {
    let Some(engine) = engine() else { return };
    let ops = Ops::new(&engine);
    let x = Mat::from_fn(40, 36, |i, j| ((i + j) % 5) as f64 * 0.2);
    let z = FeatureState::empty(40);
    let (ztz, ztx) = ops.suffstats(&z, &x).unwrap();
    assert_eq!(ztz.rows(), 0);
    assert_eq!(ztx.rows(), 0);
    let ll = ops.heldout(&x, &z, &Mat::zeros(0, 36), &[], 0.5).unwrap();
    assert!(ll.is_finite());
}
