//! Property-based tests (in-tree `propcheck` framework) for the
//! bookkeeping invariants the parallel algorithm's exactness rests on.

use pibp::coordinator::messages::{Broadcast, Summary, ToWorker, ZReport};
use pibp::linalg::{Cholesky, Mat};
use pibp::model::state::FeatureState;
use pibp::model::{CollapsedCache, LinGauss};
use pibp::propcheck::{self, Gen};
use pibp::rng::Pcg64;
use pibp::samplers::hybrid::make_shards;

fn random_state(g: &mut Gen, n: usize, k: usize) -> FeatureState {
    let mut st = FeatureState::empty(n);
    st.add_features(k);
    for i in 0..n {
        for j in 0..k {
            if g.bool(0.3) {
                st.set(i, j, 1);
            }
        }
    }
    st
}

#[test]
fn prop_feature_counts_always_consistent() {
    propcheck::run("m == column sums after arbitrary edits", 150, |g| {
        let n = g.usize_in(1, 40);
        let k = g.usize_in(1, 12);
        let mut st = random_state(g, n, k);
        for _ in 0..g.usize_in(0, 100) {
            match *g.choose(&[0, 1, 2, 3]) {
                0 => {
                    let i = g.usize_in(0, n - 1);
                    if st.k() > 0 {
                        let j = g.usize_in(0, st.k() - 1);
                        st.set(i, j, u8::from(g.bool(0.5)));
                    }
                }
                1 => {
                    st.add_features(g.usize_in(1, 3));
                }
                2 => {
                    st.compact();
                }
                _ => {}
            }
        }
        if st.check_invariants() {
            Ok(())
        } else {
            Err(format!("m={:?} recount={:?}", st.m(), st.recount()))
        }
    });
}

#[test]
fn prop_compact_preserves_nonempty_columns_and_bits() {
    propcheck::run("compact keeps exactly the non-empty columns", 100, |g| {
        let n = g.usize_in(1, 30);
        let k = g.usize_in(1, 10);
        let st0 = random_state(g, n, k);
        let mut st = st0.clone();
        let keep = st.compact();
        let want: Vec<usize> = (0..k).filter(|&j| st0.m()[j] > 0).collect();
        if keep != want {
            return Err(format!("keep {keep:?} != non-empty {want:?}"));
        }
        for (new_j, &old_j) in keep.iter().enumerate() {
            for i in 0..n {
                if st.get(i, new_j) != st0.get(i, old_j) {
                    return Err(format!("bit ({i},{old_j}) lost"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shards_partition_rows() {
    propcheck::run("make_shards partitions 0..n", 200, |g| {
        let p = g.usize_in(1, 16);
        let n = g.usize_in(p.max(1), 500);
        let shards = make_shards(n, p);
        if shards.len() != p {
            return Err("wrong shard count".into());
        }
        let mut covered = 0usize;
        let mut prev_end = 0usize;
        for s in &shards {
            if s.start != prev_end {
                return Err(format!("gap at {}", s.start));
            }
            covered += s.len();
            prev_end = s.end;
        }
        if covered != n || prev_end != n {
            return Err(format!("covered {covered} of {n}"));
        }
        let max = shards.iter().map(|s| s.len()).max().unwrap();
        let min = shards.iter().map(|s| s.len()).min().unwrap();
        if max - min > 1 {
            return Err("unbalanced".into());
        }
        Ok(())
    });
}

#[test]
fn prop_message_roundtrip() {
    propcheck::run("wire encode∘decode = id", 100, |g| {
        let k = g.usize_in(0, 8);
        let d = g.usize_in(1, 10);
        let n = g.usize_in(1, 25);
        let mut rng = Pcg64::new(g.seed ^ 0xABCD);
        let b = Broadcast {
            iter: g.usize_in(0, 1000) as u32,
            a: Mat::from_fn(k, d, |_, _| rng.normal()),
            pi: (0..k).map(|_| rng.uniform()).collect(),
            sigma_x: rng.uniform() + 0.1,
            sigma_a: rng.uniform() + 0.1,
            alpha: rng.uniform() * 3.0,
            p_prime: g.usize_in(0, 7) as u32,
            keep: (0..g.usize_in(0, k)).map(|i| i as u32).collect(),
            k_star: g.usize_in(0, 3) as u32,
            tail_owner: g.usize_in(0, 7) as u32,
            demote: (0..g.usize_in(0, 3)).map(|i| i as u32).collect(),
        };
        let msg = ToWorker::Run(b);
        if ToWorker::decode(&msg.encode()).map_err(|e| e.to_string())? != msg {
            return Err("broadcast roundtrip".into());
        }
        let tail_k = g.usize_in(0, 4);
        let s = Summary {
            worker: 1,
            iter: 2,
            m_local: (0..k).map(|_| rng.below(100)).collect(),
            ztz: Mat::from_fn(k, k, |_, _| rng.normal()),
            ztx: Mat::from_fn(k, d, |_, _| rng.normal()),
            tr_xx: rng.uniform() * 100.0,
            tail: if g.bool(0.5) { Some(random_state(g, n, tail_k)) } else { None },
            busy_s: rng.uniform(),
        };
        if Summary::decode(&s.encode()).map_err(|e| e.to_string())? != s {
            return Err("summary roundtrip".into());
        }
        let z = ZReport { worker: 0, z: random_state(g, n, k) };
        if ZReport::decode(&z.encode()).map_err(|e| e.to_string())? != z {
            return Err("zreport roundtrip".into());
        }
        Ok(())
    });
}

#[test]
fn prop_collapsed_cache_tracks_fresh_rebuild() {
    propcheck::run("cache == fresh after random row edits", 60, |g| {
        let n = g.usize_in(5, 30);
        let k = g.usize_in(1, 6);
        let d = g.usize_in(2, 8);
        let mut rng = Pcg64::new(g.seed ^ 0x77);
        let mut z = random_state(g, n, k);
        let x = Mat::from_fn(n, d, |_, _| rng.normal());
        let lg = LinGauss::new(0.5, 1.2);
        let mut cache = CollapsedCache::new(&x, &z.to_mat(), lg.ratio());
        for _ in 0..g.usize_in(1, 60) {
            let row = g.usize_in(0, n - 1);
            let zr = z.row_f64(row);
            let xr: Vec<f64> = x.row(row).to_vec();
            if !cache.remove_row(&zr, &xr) {
                cache.refresh(&x, &z.to_mat(), lg.ratio());
                continue;
            }
            let j = g.usize_in(0, k - 1);
            if g.bool(0.6) {
                z.set(row, j, 1 - z.get(row, j));
            }
            if !cache.insert_row(&z.row_f64(row), &xr) {
                cache.refresh(&x, &z.to_mat(), lg.ratio());
            }
        }
        let got = cache.loglik(&lg);
        let want = lg.collapsed_loglik(&x, &z.to_mat());
        if (got - want).abs() < 1e-4 * want.abs().max(1.0) {
            Ok(())
        } else {
            Err(format!("cache {got} vs fresh {want}"))
        }
    });
}

#[test]
fn prop_cholesky_solves_random_spd() {
    propcheck::run("chol solve satisfies Ax=b", 120, |g| {
        let n = g.usize_in(1, 12);
        let mut rng = Pcg64::new(g.seed ^ 0x11);
        let b_mat = Mat::from_fn(n + 2, n, |_, _| rng.normal());
        let mut a = b_mat.gram();
        a.add_diag(g.f64_in(0.1, 2.0));
        let ch = Cholesky::new(&a).ok_or("not PD".to_string())?;
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let x = ch.solve_vec(&b);
        let ax = a.matvec(&x);
        let err: f64 = ax.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max);
        if err < 1e-7 {
            Ok(())
        } else {
            Err(format!("residual {err}"))
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    use pibp::config::json::Json;
    propcheck::run("json display∘parse = id", 120, |g| {
        fn gen_value(g: &mut Gen, depth: usize) -> Json {
            match (*g.choose(&[0, 1, 2, 3, 4, 5]), depth) {
                (0, _) => Json::Null,
                (1, _) => Json::Bool(g.bool(0.5)),
                (2, _) => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                (3, _) => Json::Str(format!("s{}-\"q\"\n", g.usize_in(0, 99))),
                (4, d) if d < 3 => {
                    let n = g.usize_in(0, 4);
                    Json::Arr((0..n).map(|_| gen_value(g, d + 1)).collect())
                }
                (_, d) if d < 3 => {
                    let n = g.usize_in(0, 4);
                    Json::Obj(
                        (0..n)
                            .map(|i| (format!("k{i}"), gen_value(g, d + 1)))
                            .collect(),
                    )
                }
                _ => Json::Num(1.0),
            }
        }
        let v = gen_value(g, 0);
        let back = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        if back == v {
            Ok(())
        } else {
            Err(format!("{v} != {back}"))
        }
    });
}

#[test]
fn prop_rng_split_streams_disjoint() {
    propcheck::run("split streams do not collide", 50, |g| {
        let root = Pcg64::new(g.seed);
        let t1 = g.usize_in(0, 1000) as u64;
        let t2 = t1 + 1 + g.usize_in(0, 1000) as u64;
        let mut a = root.split(t1);
        let mut b = root.split(t2);
        let mut same = 0;
        for _ in 0..200 {
            if a.next_u64() == b.next_u64() {
                same += 1;
            }
        }
        if same == 0 {
            Ok(())
        } else {
            Err(format!("{same} collisions"))
        }
    });
}
