//! Observability must be provably non-perturbing: the chain a run
//! produces is **bit-identical** for every obs level (off / counters /
//! full), across the (P, T) grid, and across a checkpoint boundary where
//! the obs level changes between the writing run and the resuming run.
//!
//! Why decoded chain state and not raw checkpoint bytes: checkpoints
//! carry *measured* timing (trace `vtime_s`/`wall_s`, the coordinator's
//! virtual clock), which legitimately differs between any two runs on a
//! real machine — with or without obs. The determinism contract is about
//! the chain (Z, A, π, σ, α, the eval stream, the reservoir), so that is
//! what these tests compare, at the bit level.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use pibp::config::{ObsLevel, RunConfig, SamplerKind};
use pibp::runner::{self, RunOutcome};

/// Serialises the tests in this binary: the obs registry (level +
/// counters) is process-global and `runner::run` sets the level from the
/// config. Chain bits are immune to level flips by design — that is the
/// property under test — but serialising keeps each run's report
/// self-consistent.
static GATE: Mutex<()> = Mutex::new(());

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pibp_obs_eq_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_cfg(p: usize, t: usize, dir: &Path) -> RunConfig {
    RunConfig {
        n: 120,
        iters: 8,
        eval_every: 3,
        sampler: SamplerKind::Hybrid,
        processors: p,
        threads_per_worker: t,
        seed: 41,
        keep_samples: 8,
        out_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    }
}

/// Bit-level chain equality: global parameters, every reservoir sample,
/// and the held-out trace (chain columns only — never measured time).
fn assert_chains_identical(a: &RunOutcome, b: &RunOutcome, tag: &str) {
    let (fa, fb) = (&a.final_params, &b.final_params);
    assert_eq!(fa.k(), fb.k(), "{tag}: K diverged");
    assert_eq!(fa.alpha.to_bits(), fb.alpha.to_bits(), "{tag}: alpha diverged");
    assert_eq!(
        fa.lg.sigma_x.to_bits(),
        fb.lg.sigma_x.to_bits(),
        "{tag}: sigma_x diverged"
    );
    assert_eq!(
        fa.lg.sigma_a.to_bits(),
        fb.lg.sigma_a.to_bits(),
        "{tag}: sigma_a diverged"
    );
    let pi_a: Vec<u64> = fa.pi.iter().map(|v| v.to_bits()).collect();
    let pi_b: Vec<u64> = fb.pi.iter().map(|v| v.to_bits()).collect();
    assert_eq!(pi_a, pi_b, "{tag}: π diverged");
    assert!(fa.a.max_abs_diff(&fb.a) == 0.0, "{tag}: loadings A diverged");
    assert_eq!(
        a.reservoir.samples(),
        b.reservoir.samples(),
        "{tag}: reservoir samples diverged"
    );
    assert_eq!(
        a.trace.points.len(),
        b.trace.points.len(),
        "{tag}: trace lengths diverged"
    );
    for (pa, pb) in a.trace.points.iter().zip(&b.trace.points) {
        assert_eq!(pa.iter, pb.iter, "{tag}: trace iters diverged");
        assert_eq!(pa.k, pb.k, "{tag}: trace K at iter {} diverged", pa.iter);
        assert_eq!(
            pa.heldout.to_bits(),
            pb.heldout.to_bits(),
            "{tag}: held-out metric at iter {} diverged",
            pa.iter
        );
        assert_eq!(pa.sigma_x.to_bits(), pb.sigma_x.to_bits(), "{tag}: trace σx");
        assert_eq!(pa.alpha.to_bits(), pb.alpha.to_bits(), "{tag}: trace α");
    }
    assert!(a.final_k > 0, "{tag}: chain never grew a feature");
}

/// The tentpole guarantee: for every (P, T) in the grid, a run at
/// obs=counters and obs=full is bit-identical to the obs=off reference.
/// Obs probes draw no RNG and change no merge order, so the chain cannot
/// tell whether it is being watched.
#[test]
fn obs_level_never_perturbs_the_chain_across_p_t_grid() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    for p in [1usize, 4] {
        for t in [1usize, 4] {
            let dir = tmp_dir(&format!("grid_{p}_{t}"));
            let reference = runner::run(&run_cfg(p, t, &dir), |_| {}).unwrap();
            for level in [ObsLevel::Counters, ObsLevel::Full] {
                let mut cfg = run_cfg(p, t, &dir);
                cfg.obs = level;
                let watched = runner::run(&cfg, |_| {}).unwrap();
                assert_chains_identical(
                    &reference,
                    &watched,
                    &format!("P={p} T={t} obs={}", level.name()),
                );
            }
        }
    }
}

/// Toggling obs at a checkpoint boundary is also invisible to the chain:
/// a run checkpointed under one obs level and resumed under another must
/// match the uninterrupted obs=off reference bit-for-bit, in both
/// directions. (Works because obs keys are excluded from the resume
/// fingerprint, like `kernel` and `threads_per_worker`.)
#[test]
fn resume_with_different_obs_level_is_bit_exact() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let (p, t) = (2usize, 2usize);
    let dir = tmp_dir("crossover");
    let reference = runner::run(&run_cfg(p, t, &dir), |_| {}).unwrap();

    for (write_level, resume_level) in
        [(ObsLevel::Off, "full"), (ObsLevel::Full, "off")]
    {
        let tag = format!("obs {}→{resume_level}", write_level.name());
        let ckpt = dir.join(format!("cross_{}.pibp", write_level.name()));
        let mut part = run_cfg(p, t, &dir);
        part.obs = write_level;
        part.iters = 4;
        part.checkpoint_every = 4;
        part.checkpoint_path = ckpt.to_string_lossy().into_owned();
        runner::run(&part, |_| {}).unwrap();

        let overrides = vec![
            ("iters".to_string(), "8".to_string()),
            ("obs".to_string(), resume_level.to_string()),
        ];
        let (_, resumed) = runner::resume(&ckpt, &overrides, |_| {}).unwrap();
        assert_chains_identical(&reference, &resumed, &tag);
    }
}
