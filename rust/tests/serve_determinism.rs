//! Byte-level determinism of the posterior-serving fan-out.
//!
//! `PredictEngine` fans queries out across posterior samples: each sample
//! infers on its own derived stream (`split(9000 + s)`) into a private
//! buffer, and buffers merge **in sample order** — never in completion
//! order. Consequently every query result must be byte-identical
//!
//! * for every thread count T (pool widths 1, 2, 4),
//! * for every scheduling substrate (inline / persistent pool / scoped
//!   respawn — the latter two shuffle which OS thread finishes first), and
//! * across repeated runs on the same warm pool (arrival order is
//!   nondeterministic at the OS level; the answers must not be).

use pibp::linalg::Mat;
use pibp::model::missing::Mask;
use pibp::model::state::FeatureState;
use pibp::parallel::ParallelCtx;
use pibp::rng::Pcg64;
use pibp::serve::{PosteriorSample, PredictEngine};

/// Planted model + S jittered posterior samples around its truth.
fn planted(n: usize, k: usize, d: usize, s_count: usize, seed: u64)
           -> (Mat, Vec<PosteriorSample>) {
    let mut rng = Pcg64::new(seed);
    let mut z = FeatureState::empty(n);
    z.add_features(k);
    for i in 0..n {
        for j in 0..k {
            if rng.bernoulli(0.5) {
                z.set(i, j, 1);
            }
        }
    }
    let a = Mat::from_fn(k, d, |_, _| 2.0 * rng.normal());
    let mut x = z.to_mat().matmul(&a);
    for v in x.as_mut_slice().iter_mut() {
        *v += 0.15 * rng.normal();
    }
    let samples = (0..s_count)
        .map(|s| {
            let mut a_s = a.clone();
            for v in a_s.as_mut_slice().iter_mut() {
                *v += 0.03 * rng.normal();
            }
            PosteriorSample {
                iter: s as u64 + 1,
                z: z.clone(),
                a: a_s,
                pi: vec![0.5; k],
                sigma_x: 0.2,
                sigma_a: 1.0,
                alpha: 1.0,
            }
        })
        .collect();
    (x, samples)
}

fn mat_bits(m: &Mat) -> Vec<u64> {
    m.as_slice().iter().map(|v| v.to_bits()).collect()
}

#[test]
fn predict_engine_is_byte_identical_for_every_thread_count() {
    // 7 samples ⇒ ragged chunking at T = 2 and 4; 30 rows of queries
    let (x, samples) = planted(30, 3, 12, 7, 1);
    let mut mrng = Pcg64::new(2);
    let mask = Mask::random(30, 12, 0.3, &mut mrng);
    let seed = 11u64;

    let base_engine = PredictEngine::new(&samples, 3, 1);
    let imp = mat_bits(&base_engine.impute(&x, &mask, seed));
    let rec = mat_bits(&base_engine.reconstruct(&x, seed));
    let ll = base_engine.heldout_loglik(&x, seed);
    let ll_bits: Vec<u64> = ll.per_row.iter().map(|v| v.to_bits()).collect();

    for t in [1usize, 2, 4] {
        let engine = PredictEngine::new(&samples, 3, t);
        assert_eq!(
            mat_bits(&engine.impute(&x, &mask, seed)),
            imp,
            "imputation bytes diverged at T={t}"
        );
        assert_eq!(
            mat_bits(&engine.reconstruct(&x, seed)),
            rec,
            "reconstruction bytes diverged at T={t}"
        );
        let got = engine.heldout_loglik(&x, seed);
        assert_eq!(got.total.to_bits(), ll.total.to_bits(),
                   "heldout total diverged at T={t}");
        let got_bits: Vec<u64> = got.per_row.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, ll_bits, "heldout per-row diverged at T={t}");
    }
}

#[test]
fn predict_engine_is_invariant_to_scheduling_and_arrival_order() {
    let (x, samples) = planted(24, 3, 10, 6, 5);
    let mut mrng = Pcg64::new(6);
    let mask = Mask::random(24, 10, 0.25, &mut mrng);
    let seed = 7u64;

    let inline = PredictEngine::with_ctx(&samples, 3, ParallelCtx::inline());
    let imp = mat_bits(&inline.impute(&x, &mask, seed));
    let rec = mat_bits(&inline.reconstruct(&x, seed));
    let ll_total = inline.heldout_loglik(&x, seed).total.to_bits();

    // one warm pool, queried repeatedly: OS scheduling shuffles which
    // sample task lands ("arrives") first on every call, yet the merged
    // bytes must never move — likewise for scoped respawn, whose thread
    // set is fresh (and differently interleaved) on every call
    let pooled = PredictEngine::with_ctx(&samples, 3, ParallelCtx::pooled(4));
    let scoped = PredictEngine::with_ctx(&samples, 3, ParallelCtx::scoped(3));
    for round in 0..3 {
        for (name, engine) in [("pooled", &pooled), ("scoped", &scoped)] {
            assert_eq!(
                mat_bits(&engine.impute(&x, &mask, seed)),
                imp,
                "{name} imputation bytes moved (round {round})"
            );
            assert_eq!(
                mat_bits(&engine.reconstruct(&x, seed)),
                rec,
                "{name} reconstruction bytes moved (round {round})"
            );
            assert_eq!(
                engine.heldout_loglik(&x, seed).total.to_bits(),
                ll_total,
                "{name} heldout total moved (round {round})"
            );
        }
    }
}

#[test]
fn zero_threads_clamps_to_inline_and_matches() {
    let (x, samples) = planted(12, 2, 8, 3, 9);
    let seed = 3u64;
    let t0 = PredictEngine::new(&samples, 2, 0);
    let t1 = PredictEngine::new(&samples, 2, 1);
    assert_eq!(
        mat_bits(&t0.reconstruct(&x, seed)),
        mat_bits(&t1.reconstruct(&x, seed)),
        "--threads 0 must behave exactly like inline"
    );
}
