//! Numerical-hardening regression tests for the collapsed cache.
//!
//! Two claims pinned here (ISSUE 4):
//! 1. the updatable Cholesky factor keeps `log|M|` within 1e-8 of a fresh
//!    factorisation over thousands of remove/insert cycles at K ≈ 20 —
//!    the regime where the old summed determinant-lemma deltas drift;
//! 2. the ratio-reparameterised σ-MH path (`loglik_at_ratio`) agrees with
//!    the from-scratch oracle `lg.collapsed_loglik(&x, &z)` to 1e-9
//!    (relative) across a grid of (σ_X, σ_A), so σ proposals never need
//!    to touch X or Z.

use pibp::linalg::{det_lemma_delta, Cholesky};
use pibp::model::{CollapsedCache, LinGauss};
use pibp::rng::Pcg64;
use pibp::testutil::drift_problem as problem;

/// Thousands of remove/flip/insert cycles at K≈20: the cache's factor-based
/// logdet must stay within 1e-8 of a fresh factorisation. A shadow
/// accumulator replaying the same cycles as summed `det_lemma_delta`s
/// documents the drift the factor avoids (it is strictly worse or equal;
/// we only hard-assert the factor).
#[test]
fn drift_stress_logdet_stays_exact() {
    let n = 80;
    let k = 20;
    let d = 12;
    let (x, z, lg) = problem(n, k, d, 91);
    let mut zdyn = z.clone();
    let mut cache = CollapsedCache::new(&x, &zdyn, lg.ratio());
    // shadow: the retired summed-delta path, replayed on the same cycles
    let mut summed_logdet = cache.logdet;
    let mut rng = Pcg64::new(92);
    let mut cycles = 0usize;
    for step in 0..4000 {
        let i = step % n;
        let zr = zdyn.row(i).to_vec();
        let xr = x.row(i).to_vec();
        let delta_rm = det_lemma_delta(&cache.minv, &zr, -1.0);
        if !cache.remove_row(&zr, &xr) {
            cache.refresh(&x, &zdyn, lg.ratio());
            summed_logdet = cache.logdet;
            continue;
        }
        summed_logdet += delta_rm;
        let mut znew = zr.clone();
        let flip = (step * 7) % k;
        if rng.bernoulli(0.5) {
            znew[flip] = 1.0 - znew[flip];
        }
        let delta_in = det_lemma_delta(&cache.minv, &znew, 1.0);
        if !cache.insert_row(&znew, &xr) {
            cache.refresh(&x, &zdyn, lg.ratio());
            summed_logdet = cache.logdet;
            continue;
        }
        summed_logdet += delta_in;
        for (j, &v) in znew.iter().enumerate() {
            zdyn[(i, j)] = v;
        }
        cycles += 1;
    }
    assert!(cycles > 3000, "stress loop degenerated: only {cycles} cycles");
    // fresh factorisation of the final M
    let mut m = zdyn.gram();
    m.add_diag(lg.ratio());
    let want = Cholesky::new(&m).expect("M PD").logdet();
    let factor_err = (cache.logdet - want).abs();
    let summed_err = (summed_logdet - want).abs();
    assert!(
        factor_err < 1e-8,
        "updatable factor drifted: |{} - {}| = {factor_err:.3e} \
         (summed-delta shadow error for reference: {summed_err:.3e})",
        cache.logdet,
        want
    );
    // sanity: the factor is not meaningfully worse than the path it
    // replaced (the summed deltas inherit the SM inverse's drift; the
    // factor does not — equality can only happen if neither drifted)
    assert!(
        factor_err <= summed_err + 1e-9,
        "factor ({factor_err:.3e}) worse than summed deltas ({summed_err:.3e})"
    );
}

/// `loglik_at_ratio` from the cached sufficient statistics must match the
/// from-scratch oracle to 1e-9 relative across a (σ_X, σ_A) grid — this is
/// the σ-MH chain-equivalence guarantee: proposals evaluated N-free sample
/// the same posterior as the old full recomputation.
#[test]
fn sigma_ratio_path_matches_oracle_grid() {
    for (n, k, d, seed) in [(60, 6, 10, 93), (120, 12, 8, 94)] {
        let (x, z, lg0) = problem(n, k, d, seed);
        let cache = CollapsedCache::new(&x, &z, lg0.ratio());
        for &sx in &[0.1, 0.3, 0.5, 1.0, 2.5] {
            for &sa in &[0.2, 0.7, 1.1, 3.0] {
                let prop = LinGauss::new(sx, sa);
                let eval = cache
                    .loglik_at_ratio(&prop)
                    .expect("M' = ZtZ + r'I is PD");
                let want = prop.collapsed_loglik(&x, &z);
                let tol = 1e-9 * want.abs().max(1.0);
                assert!(
                    (eval.loglik - want).abs() < tol,
                    "n={n} k={k} sx={sx} sa={sa}: ratio path {} vs oracle {}",
                    eval.loglik,
                    want
                );
            }
        }
    }
}

/// The ratio path stays pinned to the oracle even from a *warm* cache that
/// has been through many rank-1 edits (the state σ-MH actually sees at the
/// end of a sweep), and adopting an accepted evaluation leaves the cache
/// bit-consistent with a fresh build at the new ratio.
#[test]
fn sigma_ratio_path_from_warm_cache_and_adopt() {
    let n = 70;
    let k = 8;
    let (x, z, lg0) = problem(n, k, 9, 95);
    let mut zdyn = z.clone();
    let mut cache = CollapsedCache::new(&x, &zdyn, lg0.ratio());
    let mut rng = Pcg64::new(96);
    for step in 0..600 {
        let i = step % n;
        let zr = zdyn.row(i).to_vec();
        let xr = x.row(i).to_vec();
        if !cache.remove_row(&zr, &xr) {
            cache.refresh(&x, &zdyn, lg0.ratio());
            continue;
        }
        let mut znew = zr;
        let flip = (step * 3) % k;
        if rng.bernoulli(0.5) {
            znew[flip] = 1.0 - znew[flip];
        }
        if !cache.insert_row(&znew, &xr) {
            cache.refresh(&x, &zdyn, lg0.ratio());
            continue;
        }
        for (j, &v) in znew.iter().enumerate() {
            zdyn[(i, j)] = v;
        }
    }
    let prop = LinGauss::new(0.8, 0.9);
    let eval = cache.loglik_at_ratio(&prop).expect("PD");
    let want = prop.collapsed_loglik(&x, &zdyn);
    // warm-cache E/G carry bounded drift — still far inside 1e-6
    assert!(
        (eval.loglik - want).abs() < 1e-6 * want.abs().max(1.0),
        "warm ratio path {} vs oracle {}",
        eval.loglik,
        want
    );
    cache.adopt(eval);
    let fresh = CollapsedCache::new(&x, &zdyn, prop.ratio());
    assert!(
        (cache.loglik(&prop) - fresh.loglik(&prop)).abs()
            < 1e-6 * fresh.loglik(&prop).abs().max(1.0),
        "adopted cache diverges from fresh build"
    );
    assert!((cache.logdet - fresh.logdet).abs() < 1e-9, "adopted logdet not exact");
}
