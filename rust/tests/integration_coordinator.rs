//! End-to-end integration of the parallel coordinator: convergence on
//! Cambridge data, agreement with the serial hybrid oracle, PJRT-backend
//! equivalence, and bookkeeping invariants under promotion/compaction.

use std::path::Path;

use pibp::config::{Backend, CommModel};
use pibp::coordinator::{Coordinator, CoordinatorConfig};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::model::state::Kernel;
use pibp::model::LinGauss;
use pibp::rng::Pcg64;
use pibp::samplers::eval::HeldoutEval;
use pibp::samplers::hybrid::{HybridConfig, HybridSampler};
use pibp::samplers::SamplerOptions;

fn cambridge(n: usize, seed: u64) -> pibp::linalg::Mat {
    generate(&CambridgeConfig { n, seed, ..Default::default() }).0.x
}

fn cfg(p: usize, seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        processors: p,
        sub_iters: 5,
        threads_per_worker: 1,
        kernel: Kernel::Scalar,
        seed,
        lg: LinGauss::new(0.5, 1.0),
        alpha: 1.0,
        opts: SamplerOptions::default(),
        backend: Backend::Native,
        artifacts_dir: Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        comm: CommModel::default(),
        ..Default::default()
    }
}

#[test]
fn parallel_converges_on_cambridge() {
    let x = cambridge(200, 1);
    let mut coord = Coordinator::new(&x, cfg(3, 2)).unwrap();
    let mut ks = vec![];
    for _ in 0..40 {
        let rec = coord.step().unwrap();
        ks.push(rec.k);
        assert!(rec.sigma_x > 0.0 && rec.sigma_x < 3.0);
        assert!(rec.vtime_iter_s > 0.0);
        assert!(rec.comm_bytes > 0);
    }
    let tail = &ks[25..];
    let mean_k = tail.iter().sum::<usize>() as f64 / tail.len() as f64;
    assert!((3.0..=13.0).contains(&mean_k), "K trace {ks:?}");
}

#[test]
fn parallel_matches_serial_oracle_distributionally() {
    // same posterior target: compare long-run held-out loglik plateaus
    let (ds, _) = generate(&CambridgeConfig { n: 240, seed: 3, ..Default::default() });
    let (train, test) = ds.split_heldout(0.1);

    // serial oracle (samplers::hybrid), P=2 equivalent workload
    let mut rng = Pcg64::new(4);
    let mut serial = HybridSampler::new(
        train.x.clone(),
        LinGauss::new(0.5, 1.0),
        1.0,
        HybridConfig {
            processors: 2,
            sub_iters: 5,
            opts: SamplerOptions::default(),
            ..Default::default()
        },
        4,
    );
    let mut ev1 = HeldoutEval::new(test.x.clone(), 3);
    let mut serial_scores = vec![];
    for i in 0..45 {
        serial.step();
        if i >= 30 {
            serial_scores.push(ev1.evaluate(&serial.params, &mut rng));
        }
    }

    // parallel coordinator
    let mut coord = Coordinator::new(&train.x, cfg(2, 5)).unwrap();
    let mut ev2 = HeldoutEval::new(test.x.clone(), 3);
    let mut rng2 = Pcg64::new(6);
    let mut par_scores = vec![];
    for i in 0..45 {
        coord.step().unwrap();
        if i >= 30 {
            par_scores.push(ev2.evaluate(coord.params(), &mut rng2));
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (ms, mp) = (mean(&serial_scores), mean(&par_scores));
    // plateaus must agree to within a few per-row log-lik units
    let tol = 0.15 * ms.abs().max(50.0);
    assert!(
        (ms - mp).abs() < tol,
        "serial plateau {ms:.1} vs parallel {mp:.1} (tol {tol:.1})"
    );
}

#[test]
fn deterministic_given_seed() {
    let x = cambridge(120, 7);
    let run = |seed: u64| {
        let mut coord = Coordinator::new(&x, cfg(3, seed)).unwrap();
        (0..10)
            .map(|_| {
                let r = coord.step().unwrap();
                (r.k, r.sigma_x.to_bits(), r.alpha.to_bits())
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(run(11), run(11), "same seed must give identical chains");
    assert_ne!(run(11), run(12));
}

#[test]
fn gather_z_matches_global_counts() {
    let x = cambridge(150, 8);
    let mut coord = Coordinator::new(&x, cfg(4, 9)).unwrap();
    for _ in 0..12 {
        coord.step().unwrap();
    }
    let z = coord.gather_z().unwrap();
    assert_eq!(z.n(), 150);
    assert_eq!(z.k(), coord.k(), "gathered K must match params");
    assert!(z.check_invariants());
    // column sums must equal the master's merged counts
    assert_eq!(z.m(), coord.m_global(), "m mismatch");
    // every feature the master kept is non-empty
    assert!(z.m().iter().all(|&m| m > 0));
}

#[test]
fn more_processors_same_quality() {
    let (ds, _) = generate(&CambridgeConfig { n: 200, seed: 10, ..Default::default() });
    let (train, test) = ds.split_heldout(0.1);
    let mut plateaus = vec![];
    for p in [1usize, 3, 5] {
        let mut coord = Coordinator::new(&train.x, cfg(p, 20 + p as u64)).unwrap();
        let mut ev = HeldoutEval::new(test.x.clone(), 3);
        let mut rng = Pcg64::new(30 + p as u64);
        let mut scores = vec![];
        for i in 0..40 {
            coord.step().unwrap();
            if i >= 28 {
                scores.push(ev.evaluate(coord.params(), &mut rng));
            }
        }
        plateaus.push(scores.iter().sum::<f64>() / scores.len() as f64);
    }
    let spread = plateaus
        .iter()
        .fold(f64::NEG_INFINITY, |a, &b| a.max(b))
        - plateaus.iter().fold(f64::INFINITY, |a, &b| a.min(b));
    assert!(
        spread < 0.2 * plateaus[0].abs().max(50.0),
        "quality differs across P: {plateaus:?}"
    );
}

#[test]
fn pjrt_backend_converges_like_native() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return; // artifacts not built
    }
    let (ds, _) = generate(&CambridgeConfig { n: 120, seed: 11, ..Default::default() });
    let (train, test) = ds.split_heldout(0.1);
    let mut plateaus = vec![];
    for backend in [Backend::Native, Backend::Pjrt] {
        let mut c = cfg(2, 40);
        c.backend = backend;
        let mut coord = Coordinator::new(&train.x, c).unwrap();
        let mut ev = HeldoutEval::new(test.x.clone(), 3);
        let mut rng = Pcg64::new(41);
        let mut scores = vec![];
        for i in 0..35 {
            coord.step().unwrap();
            if i >= 25 {
                scores.push(ev.evaluate(coord.params(), &mut rng));
            }
        }
        plateaus.push(scores.iter().sum::<f64>() / scores.len() as f64);
    }
    assert!(
        (plateaus[0] - plateaus[1]).abs() < 0.2 * plateaus[0].abs().max(50.0),
        "native {} vs pjrt {}", plateaus[0], plateaus[1]
    );
}

#[test]
fn vtime_speedup_shape() {
    // more processors ⇒ smaller max-worker-busy per iteration on the same
    // data (the Figure-1 mechanism)
    let x = cambridge(400, 12);
    let mut busy = vec![];
    for p in [1usize, 4] {
        let mut coord = Coordinator::new(&x, cfg(p, 50)).unwrap();
        let mut acc = 0.0;
        for _ in 0..8 {
            let rec = coord.step().unwrap();
            acc += rec.max_worker_busy_s;
        }
        busy.push(acc);
    }
    assert!(
        busy[1] < 0.6 * busy[0],
        "P=4 max-worker busy {:.4}s not < 0.6× P=1 {:.4}s",
        busy[1], busy[0]
    );
}
