//! Cross-sampler integration: all four samplers on the same data must
//! agree on the quantities the posterior determines (held-out plateau,
//! noise estimate), and the runner must produce comparable traces.

use pibp::config::{RunConfig, SamplerKind};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::metrics::ess;
use pibp::model::LinGauss;
use pibp::rng::Pcg64;
use pibp::runner;
use pibp::samplers::collapsed::{CollapsedGibbs, Mode};
use pibp::samplers::eval::HeldoutEval;
use pibp::samplers::SamplerOptions;

fn cfg(sampler: SamplerKind, iters: usize) -> RunConfig {
    RunConfig {
        n: 150,
        iters,
        eval_every: 3,
        seed: 13,
        sampler,
        ..Default::default()
    }
}

#[test]
fn all_samplers_reach_comparable_plateaus() {
    // the three exact samplers (collapsed, accelerated, hybrid) target the
    // same posterior; their held-out plateaus must agree.
    let mut plateaus = vec![];
    for kind in [SamplerKind::Collapsed, SamplerKind::Accelerated, SamplerKind::Hybrid] {
        let out = runner::run(&cfg(kind, 40), |_| {}).unwrap();
        plateaus.push((kind, out.trace.plateau(0.3)));
    }
    let vals: Vec<f64> = plateaus.iter().map(|p| p.1).collect();
    let lo = vals.iter().fold(f64::INFINITY, |a, &b| a.min(b));
    let hi = vals.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
    assert!(
        hi - lo < 0.2 * hi.abs().max(50.0),
        "plateaus diverge: {plateaus:?}"
    );
}

#[test]
fn sigma_x_recovered_by_every_sampler() {
    for kind in [SamplerKind::Collapsed, SamplerKind::Hybrid] {
        let out = runner::run(&cfg(kind, 40), |_| {}).unwrap();
        let sx = out.trace.last().unwrap().sigma_x;
        assert!(
            (sx - 0.5).abs() < 0.15,
            "{kind:?} sigma_x={sx}, truth 0.5"
        );
    }
}

#[test]
fn uncollapsed_baseline_underperforms_on_heldout() {
    // paper §2 motivation: the finite uncollapsed sampler mixes poorly —
    // its plateau should not beat the hybrid's.
    let hybrid = runner::run(&cfg(SamplerKind::Hybrid, 40), |_| {}).unwrap();
    let uncoll = runner::run(&cfg(SamplerKind::Uncollapsed, 40), |_| {}).unwrap();
    assert!(
        uncoll.trace.plateau(0.3) <= hybrid.trace.plateau(0.3) + 20.0,
        "uncollapsed {} vs hybrid {}",
        uncoll.trace.plateau(0.3),
        hybrid.trace.plateau(0.3)
    );
}

#[test]
fn collapsed_chain_ess_is_finite() {
    let (ds, _) = generate(&CambridgeConfig { n: 100, seed: 5, ..Default::default() });
    let mut rng = Pcg64::new(6);
    let mut s = CollapsedGibbs::new(
        ds.x.clone(),
        LinGauss::new(0.5, 1.0),
        1.0,
        Mode::Exact,
        SamplerOptions { sample_sigmas: false, ..Default::default() },
        &mut rng,
    );
    let joints: Vec<f64> = (0..60).map(|_| s.step(&mut rng).train_joint).collect();
    let e = ess(&joints[20..]);
    assert!(e.is_finite() && e >= 1.0);
}

#[test]
fn heldout_metric_is_comparable_across_representations() {
    // evaluating the SAME params twice with different evaluator instances
    // must agree (warm-start independence at plateau).
    let out = runner::run(&cfg(SamplerKind::Hybrid, 30), |_| {}).unwrap();
    let (ds, _) = generate(&CambridgeConfig { n: 150, seed: 13, ..Default::default() });
    let (_, test) = ds.split_heldout(0.1);
    let mut rng1 = Pcg64::new(1);
    let mut rng2 = Pcg64::new(2);
    let mut ev1 = HeldoutEval::new(test.x.clone(), 5);
    let mut ev2 = HeldoutEval::new(test.x.clone(), 5);
    // let both warm up
    for _ in 0..3 {
        ev1.evaluate(&out.final_params, &mut rng1);
        ev2.evaluate(&out.final_params, &mut rng2);
    }
    let a = ev1.evaluate(&out.final_params, &mut rng1);
    let b = ev2.evaluate(&out.final_params, &mut rng2);
    assert!(
        (a - b).abs() < 0.1 * a.abs().max(20.0),
        "evaluator not reproducible: {a} vs {b}"
    );
}

#[test]
fn traces_are_monotone_in_time() {
    let out = runner::run(&cfg(SamplerKind::Hybrid, 20), |_| {}).unwrap();
    let mut prev = -1.0;
    for p in &out.trace.points {
        assert!(p.vtime_s > prev, "vtime must be strictly increasing");
        prev = p.vtime_s;
    }
}
