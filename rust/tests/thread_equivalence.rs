//! Exactness pinning of the two-level parallelism grid: P coordinator
//! workers × T intra-worker sweep threads (`crate::parallel`).
//!
//! The executor's contract is that T is a pure scheduling knob — block
//! layout and per-block RNG substreams depend only on the row range — so
//! every (P, T) coordinator must reproduce the *same* chain as the serial
//! hybrid oracle for that P, bit-for-bit, and any two T values must agree
//! with each other even in configurations the oracle does not model
//! (demotion on).
//!
//! Since the pool refactor the grid also pins **pool vs scoped-respawn**:
//! coordinator workers schedule their sweeps on persistent
//! [`pibp::parallel::ThreadPool`]s, while the oracle here is run on the
//! legacy per-call `std::thread::scope` executor (`ParallelCtx::scoped`).
//! Chain equality across the whole (P, T) grid is therefore also
//! bit-exactness of the two scheduling substrates.

use std::path::Path;

use pibp::config::{Backend, CommModel};
use pibp::coordinator::{Coordinator, CoordinatorConfig};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::linalg::Mat;
use pibp::model::state::Kernel;
use pibp::model::LinGauss;
use pibp::parallel::ParallelCtx;
use pibp::samplers::hybrid::{HybridConfig, HybridSampler};
use pibp::samplers::SamplerOptions;

const ITERS: usize = 12;

fn coord_cfg(
    p: usize,
    t: usize,
    kernel: Kernel,
    seed: u64,
    opts: SamplerOptions,
) -> CoordinatorConfig {
    CoordinatorConfig {
        processors: p,
        sub_iters: 5,
        threads_per_worker: t,
        kernel,
        seed,
        lg: LinGauss::new(0.5, 1.0),
        alpha: 1.0,
        opts,
        backend: Backend::Native,
        artifacts_dir: Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        comm: CommModel::default(),
        ..Default::default()
    }
}

/// The serial oracle does not implement the coordinator's demotion
/// optimisation, so oracle-exactness is stated with demotion off.
fn opts_no_demote() -> SamplerOptions {
    SamplerOptions { demote_below: 0, ..Default::default() }
}

/// One oracle iteration's global state, bit-level.
#[derive(Clone)]
struct IterPin {
    k: usize,
    alpha: u64,
    sigma_x: u64,
    sigma_a: u64,
    pi: Vec<u64>,
    a: Mat,
}

#[test]
fn pt_grid_reproduces_serial_oracle_chain_exactly() {
    // n = 200 so every shard spans several 32-row blocks at both P values
    // (P=1 ⇒ 7 blocks, P=4 ⇒ 2 blocks of the 50-row shards): T > 1 has
    // real work to schedule.
    let (ds, _) = generate(&CambridgeConfig { n: 200, seed: 3, ..Default::default() });
    let seed = 17u64;

    for p in [1usize, 4] {
        // ---- reference chain: the serial hybrid oracle for this P,
        //      deliberately on the legacy scoped-respawn executor so the
        //      grid below pins pool-vs-scoped bit-exactness too ----
        let mut serial = HybridSampler::new(
            ds.x.clone(),
            LinGauss::new(0.5, 1.0),
            1.0,
            HybridConfig {
                processors: p,
                sub_iters: 5,
                ctx: Some(ParallelCtx::scoped(2)),
                opts: opts_no_demote(),
                ..Default::default()
            },
            seed,
        );
        let mut pins: Vec<IterPin> = Vec::with_capacity(ITERS);
        for _ in 0..ITERS {
            let st = serial.step();
            pins.push(IterPin {
                k: st.k,
                alpha: st.alpha.to_bits(),
                sigma_x: st.sigma_x.to_bits(),
                sigma_a: st.sigma_a.to_bits(),
                pi: serial.params.pi.iter().map(|v| v.to_bits()).collect(),
                a: serial.params.a.clone(),
            });
        }
        assert!(serial.k() > 0, "P={p}: chain never instantiated a feature");

        // ---- every pooled T, on either Z kernel, must reproduce the
        //      scalar-pinned oracle bit-for-bit ----
        for t in [1usize, 2, 4] {
            for kernel in [Kernel::Scalar, Kernel::Packed] {
                let kn = kernel.name();
                let mut coord = Coordinator::new(
                    &ds.x,
                    coord_cfg(p, t, kernel, seed, opts_no_demote()),
                )
                .unwrap();
                for (it, pin) in pins.iter().enumerate() {
                    let rec = coord.step().unwrap();
                    assert_eq!(rec.k, pin.k, "P={p} T={t} {kn} iter {it}: K⁺ diverged");
                    assert_eq!(
                        rec.alpha.to_bits(),
                        pin.alpha,
                        "P={p} T={t} {kn} iter {it}: alpha diverged"
                    );
                    assert_eq!(
                        rec.sigma_x.to_bits(),
                        pin.sigma_x,
                        "P={p} T={t} {kn} iter {it}: sigma_x diverged"
                    );
                    assert_eq!(
                        rec.sigma_a.to_bits(),
                        pin.sigma_a,
                        "P={p} T={t} {kn} iter {it}: sigma_a diverged"
                    );
                    let cp = coord.params();
                    let pi_bits: Vec<u64> =
                        cp.pi.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(pi_bits, pin.pi, "P={p} T={t} {kn} iter {it}: π diverged");
                    assert_eq!(
                        cp.a.rows(),
                        pin.a.rows(),
                        "P={p} T={t} {kn} iter {it}: A rows"
                    );
                    assert!(
                        cp.a.max_abs_diff(&pin.a) == 0.0,
                        "P={p} T={t} {kn} iter {it}: loadings A diverged"
                    );
                }
                let z = coord.gather_z().unwrap();
                assert_eq!(
                    z, serial.z,
                    "P={p} T={t} {kn}: gathered Z diverged from the serial oracle"
                );
            }
        }
    }
}

#[test]
fn thread_count_is_invisible_even_with_demotion_on() {
    // Demotion is a coordinator-only optimisation the oracle doesn't
    // model; T-invariance must hold there too. Pin T=1 against T=4 on the
    // production options, chain-for-chain.
    let (ds, _) = generate(&CambridgeConfig { n: 150, seed: 9, ..Default::default() });
    let seed = 23u64;
    let run = |t: usize, kernel: Kernel| {
        let mut coord = Coordinator::new(
            &ds.x,
            coord_cfg(3, t, kernel, seed, SamplerOptions::default()),
        )
        .unwrap();
        let mut trace = Vec::new();
        for _ in 0..10 {
            let rec = coord.step().unwrap();
            trace.push((
                rec.k,
                rec.alpha.to_bits(),
                rec.sigma_x.to_bits(),
                rec.sigma_a.to_bits(),
            ));
        }
        (trace, coord.gather_z().unwrap())
    };
    let (trace1, z1) = run(1, Kernel::Scalar);
    for (t, kernel) in [(2usize, Kernel::Scalar), (4, Kernel::Scalar), (1, Kernel::Packed), (4, Kernel::Packed)]
    {
        let kn = kernel.name();
        let (trace_t, z_t) = run(t, kernel);
        assert_eq!(trace1, trace_t, "T={t} {kn} changed the chain under demotion");
        assert_eq!(z1, z_t, "T={t} {kn} changed the gathered Z under demotion");
    }
    assert!(z1.k() > 0, "chain never instantiated a feature");
}
