//! Exactness pinning of the parallel coordinator against the serial
//! hybrid oracle — the "asymptotically exact, and at P = 1 *identical*"
//! claim behind the paper's algorithm:
//!
//! 1. a P = 1 coordinator must reproduce `samplers::hybrid::HybridSampler`
//!    **chain-for-chain** (every global parameter bit-identical, every
//!    iteration) given the same root seed — both sides derive the master
//!    stream as `Pcg64::new(seed).split(1)` and worker p's stream as
//!    `Pcg64::new(seed).split(1000 + p)`, and both run each sweep under
//!    the per-row-block substream discipline of `pibp::parallel` (the
//!    (P × T) grid extension of this pin lives in
//!    `rust/tests/thread_equivalence.rs`);
//! 2. at P > 1 the master's merged sufficient statistics (m_k, ZᵀZ, ZᵀX,
//!    tr XᵀX) must match a serial shard-by-shard recomputation from the
//!    gathered global Z bit-for-bit after every global step.

use std::path::Path;

use pibp::config::{Backend, CommModel};
use pibp::coordinator::{Coordinator, CoordinatorConfig};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::linalg::Mat;
use pibp::model::state::Kernel;
use pibp::model::LinGauss;
use pibp::samplers::hybrid::{make_shards, HybridConfig, HybridSampler};
use pibp::samplers::SamplerOptions;

fn coord_cfg(p: usize, kernel: Kernel, seed: u64, opts: SamplerOptions) -> CoordinatorConfig {
    CoordinatorConfig {
        processors: p,
        sub_iters: 5,
        threads_per_worker: 1,
        kernel,
        seed,
        lg: LinGauss::new(0.5, 1.0),
        alpha: 1.0,
        opts,
        backend: Backend::Native,
        artifacts_dir: Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        comm: CommModel::default(),
        ..Default::default()
    }
}

/// The serial oracle does not implement the coordinator's demotion
/// optimisation, so exact equivalence is stated with demotion off.
fn opts_no_demote() -> SamplerOptions {
    SamplerOptions { demote_below: 0, ..Default::default() }
}

#[test]
fn p1_coordinator_reproduces_serial_hybrid_chain_exactly() {
    let (ds, _) = generate(&CambridgeConfig { n: 80, seed: 2, ..Default::default() });
    let seed = 42u64;
    let mut coord =
        Coordinator::new(&ds.x, coord_cfg(1, Kernel::Scalar, seed, opts_no_demote())).unwrap();
    let mut serial = HybridSampler::new(
        ds.x.clone(),
        LinGauss::new(0.5, 1.0),
        1.0,
        HybridConfig {
            processors: 1,
            sub_iters: 5,
            opts: opts_no_demote(),
            ..Default::default()
        },
        seed,
    );

    let mut pins: Vec<(usize, u64, u64, u64)> = Vec::new();
    for it in 0..25 {
        let rec = coord.step().unwrap();
        let st = serial.step();
        pins.push((st.k, st.alpha.to_bits(), st.sigma_x.to_bits(), st.sigma_a.to_bits()));
        assert_eq!(rec.k, st.k, "iter {it}: K⁺ diverged");
        assert_eq!(
            rec.alpha.to_bits(),
            st.alpha.to_bits(),
            "iter {it}: alpha diverged ({} vs {})",
            rec.alpha,
            st.alpha
        );
        assert_eq!(
            rec.sigma_x.to_bits(),
            st.sigma_x.to_bits(),
            "iter {it}: sigma_x diverged ({} vs {})",
            rec.sigma_x,
            st.sigma_x
        );
        assert_eq!(
            rec.sigma_a.to_bits(),
            st.sigma_a.to_bits(),
            "iter {it}: sigma_a diverged ({} vs {})",
            rec.sigma_a,
            st.sigma_a
        );
        let cp = coord.params();
        assert_eq!(cp.pi.len(), serial.params.pi.len(), "iter {it}: pi length");
        for (k, (a, b)) in cp.pi.iter().zip(&serial.params.pi).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "iter {it}: pi[{k}] diverged");
        }
        assert_eq!(cp.a.rows(), serial.params.a.rows(), "iter {it}: A rows");
        assert_eq!(cp.a.cols(), serial.params.a.cols(), "iter {it}: A cols");
        assert!(
            cp.a.max_abs_diff(&serial.params.a) == 0.0,
            "iter {it}: loadings A diverged"
        );
    }

    // The sampler must actually have done something for the test to mean
    // anything — and the feature matrices must agree bit-for-bit too.
    assert!(serial.k() > 0, "chain never instantiated a feature");
    let z = coord.gather_z().unwrap();
    assert_eq!(z, serial.z, "gathered Z diverged from the serial oracle");

    // ---- the packed kernel must reproduce the same (scalar-pinned)
    //      oracle chain, same P=1 configuration ----
    let mut packed =
        Coordinator::new(&ds.x, coord_cfg(1, Kernel::Packed, seed, opts_no_demote())).unwrap();
    for (it, pin) in pins.iter().enumerate() {
        let rec = packed.step().unwrap();
        let got = (rec.k, rec.alpha.to_bits(), rec.sigma_x.to_bits(), rec.sigma_a.to_bits());
        assert_eq!(got, *pin, "packed iter {it}: chain diverged from the scalar oracle");
    }
    let zp = packed.gather_z().unwrap();
    assert_eq!(zp, serial.z, "packed gathered Z diverged from the serial oracle");
}

#[test]
fn p4_merged_suffstats_match_serial_recomputation() {
    let n = 120usize;
    let p = 4usize;
    let (ds, _) = generate(&CambridgeConfig { n, seed: 5, ..Default::default() });
    // default options: demotion stays ON, so the merge/compaction paths
    // the production coordinator runs are the ones being pinned — on
    // both Z kernels (the packed master assembles its gram from column
    // popcounts; the recomputation below is always dense).
    for kernel in [Kernel::Scalar, Kernel::Packed] {
        let mut coord =
            Coordinator::new(&ds.x, coord_cfg(p, kernel, 7, SamplerOptions::default())).unwrap();
        let shards = make_shards(n, p);
        let d = ds.x.cols();

        let mut saw_features = false;
        for it in 0..12 {
            coord.step().unwrap();
            let merged = coord.last_merged().expect("merged stats recorded").clone();
            let z = coord.gather_z().unwrap();
            let k = z.k();
            assert_eq!(merged.m.len(), k, "iter {it}: m length");
            assert_eq!(merged.m, z.m(), "iter {it}: merged m_k vs gathered Z");
            assert_eq!(merged.ztz.rows(), k, "iter {it}: ZᵀZ shape");
            assert_eq!(merged.ztx.rows(), k, "iter {it}: ZᵀX shape");
            if k > 0 {
                saw_features = true;
            }

            // Serial recomputation, shard by shard in worker order — the same
            // accumulation sequence the master's merge performs, so agreement
            // must be bit-for-bit, not approximate.
            let mut ztz = Mat::zeros(k, k);
            let mut ztx = Mat::zeros(k, d);
            let mut tr_xx = 0.0f64;
            for sh in &shards {
                let zp = Mat::from_fn(sh.len(), k, |i, j| z.get(sh.start + i, j) as f64);
                let xp = Mat::from_fn(sh.len(), d, |i, j| ds.x[(sh.start + i, j)]);
                ztz.add_assign(&zp.gram());
                ztx.add_assign(&zp.t_matmul(&xp));
                tr_xx += xp.frob2();
            }
            assert!(
                merged.ztz.max_abs_diff(&ztz) == 0.0,
                "iter {it}: merged ZᵀZ != serial recomputation"
            );
            assert!(
                merged.ztx.max_abs_diff(&ztx) == 0.0,
                "iter {it}: merged ZᵀX != serial recomputation"
            );
            assert_eq!(
                merged.tr_xx.to_bits(),
                tr_xx.to_bits(),
                "iter {it}: merged tr XᵀX != serial recomputation"
            );
        }
        assert!(saw_features, "chain never instantiated a feature ({})", kernel.name());
    }
}

#[test]
fn per_worker_streams_are_deterministic_and_distinct() {
    // The reproducibility contract the equivalence above rests on:
    // worker streams are a pure function of (seed, worker id).
    use pibp::rng::Pcg64;
    let seed = 123u64;
    let mut a0 = Pcg64::new(seed).split(1000);
    let mut a0b = Pcg64::new(seed).split(1000);
    let mut a1 = Pcg64::new(seed).split(1001);
    let mut master = Pcg64::new(seed).split(1);
    let mut collisions = 0;
    for _ in 0..256 {
        let v0 = a0.next_u64();
        assert_eq!(v0, a0b.next_u64(), "worker stream not reproducible");
        if v0 == a1.next_u64() {
            collisions += 1;
        }
        if v0 == master.next_u64() {
            collisions += 1;
        }
    }
    assert!(collisions <= 1, "streams overlap: {collisions} collisions");
}
