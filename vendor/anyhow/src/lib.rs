//! Minimal, dependency-free stand-in for the `anyhow` error-handling crate.
//!
//! The build environment for this repository is fully offline (no crate
//! registry), so this vendored shim provides the small subset of the
//! `anyhow` 1.x API the workspace actually uses:
//!
//! * [`Error`] — an opaque error value carrying a chain of context
//!   messages (outermost first);
//! * [`Result`] — `std::result::Result<T, Error>` with an overridable
//!   error parameter, exactly like `anyhow::Result`;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`] and [`bail!`] macros.
//!
//! Formatting matches `anyhow` where observable in this workspace: `{}`
//! prints the outermost message, `{:#}` prints the whole
//! `outer: inner: …` chain on one line, and `{:?}` prints the message
//! followed by a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// An error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like `anyhow::Error`, this type deliberately does NOT implement
// `std::error::Error`: the blanket conversion below relies on that.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`] but can be
/// instantiated with any error type (`Result<T, ParseIntError>` etc.).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values (`Result` and `Option`).
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

// Does not overlap with the impl above because `Error` (a local type)
// does not implement `std::error::Error` — the same coherence argument
// the real `anyhow` rests on.
impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading /tmp/x")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading /tmp/x");
        assert_eq!(format!("{e:#}"), "reading /tmp/x: missing thing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:") && dbg.contains("missing thing"));
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
        let n = 3;
        let e = anyhow!("bad value {n} ({})", n + 1);
        assert_eq!(format!("{e}"), "bad value 3 (4)");
        fn fails() -> Result<()> {
            bail!("nope {}", 7)
        }
        assert_eq!(fails().unwrap_err().to_string(), "nope 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<f64> {
            Ok(s.parse::<f64>()?)
        }
        assert!(parse("1.5").is_ok());
        assert!(parse("x").is_err());
    }

    #[test]
    fn with_context_is_lazy_and_chains() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| format!("step {}", 2))
            .unwrap_err();
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["step 2", "missing thing"]);
    }
}
