//! Offline **API stub** of the XLA PJRT bindings (`xla` crate subset).
//!
//! The build image has no crate registry and the real PJRT bindings are
//! not vendored, but the feature-gated engine in
//! `rust/src/runtime/pjrt.rs` must not silently rot. This crate mirrors
//! exactly the API surface that engine uses — same types, same method
//! signatures — with every entry point returning [`Error::Unavailable`]
//! at runtime, so:
//!
//! * `cargo build/clippy --features pjrt` type-checks the real engine
//!   path (CI's feature-matrix job);
//! * a `pjrt`-featured binary still degrades exactly like the default
//!   stub: `Engine::load` errors at `PjRtClient::cpu()` and every caller
//!   already treats that as "PJRT unavailable, use the native backend".
//!
//! To run the AOT artifacts for real, replace this path dependency in
//! `rust/Cargo.toml` with the actual XLA PJRT bindings — the API below
//! is the contract they must satisfy.

use std::fmt;

/// Error type matching the bindings' `xla::Error` (only `Display` is
/// observed by pibp, via `anyhow!("{e}")`).
#[derive(Debug)]
pub enum Error {
    /// The stub's only inhabitant: the real bindings are not linked.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real XLA PJRT bindings \
                 (vendor/xla is an offline API stub; see its crate docs)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (tensor) handle.
#[derive(Debug, Default)]
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Self {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable("Literal::reshape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (text interchange).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Device-resident buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Matches the bindings' generic-over-argument execute.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A PJRT client (CPU plugin in pibp's deployment).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// The stub fails here, which is the earliest call on the engine's
    /// load path — `Engine::load` therefore errors cleanly and pibp
    /// falls back to the native backend, same as the default build.
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("stub"), "unhelpful error: {msg}");
    }
}
